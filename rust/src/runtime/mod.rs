//! PJRT runtime: loads AOT HLO-text artifacts and executes decode/verify
//! steps. This is the only module that touches the `xla` crate; everything
//! above it works with plain Rust types.
//!
//! Design notes:
//! * One `PjRtClient` (CPU) per [`ModelRuntime`]; clients are `Rc`-cloned and
//!   can be shared across runtimes via [`ModelRuntime::with_client`] so a
//!   multi-model experiment pays client start-up once.
//! * Executables are compiled lazily per token-count variant and cached —
//!   after warm-up the request path performs zero compilation.
//! * Request state (KV cache, router state) stays as `xla::Literal`s between
//!   steps; only logits and router top-k indices are copied to host vectors.

mod state;
mod step;

pub use state::RequestState;
pub use step::StepOutput;

use crate::models::{Model, Registry};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Compiled runtime for one model: PJRT executables per token-count variant
/// plus the model's parameters resident on the device.
pub struct ModelRuntime {
    pub model: Model,
    client: xla::PjRtClient,
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// Model parameters, uploaded once (leading step arguments).
    weights: Vec<xla::PjRtBuffer>,
    /// Host copies backing `weights`: PJRT's CopyFromLiteral is
    /// asynchronous, so the source literals must outlive the buffers.
    _weight_literals: Vec<xla::Literal>,
    /// Cumulative wall time spent inside PJRT execute (profiling).
    pub exec_wall_ns: u128,
    pub exec_calls: u64,
}

impl ModelRuntime {
    /// Load a model and create a fresh CPU PJRT client.
    pub fn load(registry: &Registry, name: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Self::with_client(registry, name, client)
    }

    /// Load a model onto an existing client (shared across runtimes).
    pub fn with_client(
        registry: &Registry,
        name: &str,
        client: xla::PjRtClient,
    ) -> Result<Self> {
        let model = registry.model(name)?;
        let (weights, lits) = load_weights(&client, &model)?;
        Ok(Self {
            model,
            client,
            exes: BTreeMap::new(),
            weights,
            _weight_literals: lits,
            exec_wall_ns: 0,
            exec_calls: 0,
        })
    }

    pub fn client(&self) -> xla::PjRtClient {
        self.client.clone()
    }

    /// Compile (and cache) the executable for a T-token step.
    pub fn ensure_variant(&mut self, t: usize) -> Result<()> {
        if self.exes.contains_key(&t) {
            return Ok(());
        }
        let path = self.model.variant_path(t)?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling T={t} variant: {e:?}"))?;
        self.exes.insert(t, exe);
        Ok(())
    }

    /// Pre-compile all token-count variants so the serving loop never
    /// compiles.
    pub fn warmup(&mut self) -> Result<()> {
        for t in self.model.token_variants() {
            self.ensure_variant(t)?;
        }
        Ok(())
    }

    /// Fresh per-request state (zero KV cache and router state).
    pub fn fresh_state(&self) -> RequestState {
        RequestState::fresh(&self.model.mini)
    }

    /// Execute one step over `tokens` (length must match an AOT variant).
    /// Writes KV at positions `[state.cache_len, state.cache_len + T)` and
    /// replaces the state's KV/router literals. The caller decides how far
    /// `cache_len` advances (speculative tokens may be rejected).
    pub fn step(&mut self, state: &mut RequestState, tokens: &[u32]) -> Result<StepOutput> {
        let t = tokens.len();
        self.ensure_variant(t)?;
        let exe = self.exes.get(&t).expect("ensured above");

        let tok_i32: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let tok_lit = xla::Literal::vec1(&tok_i32);
        let len_lit = xla::Literal::scalar(state.cache_len as i32);

        let start = Instant::now(); // lint:allow(wall-clock): exec_wall_ns profiling counter, host-only
        // Per-step uploads (tokens/cache_len are tiny; KV/router state are
        // the only real copies). Weights stay device-resident.
        let up = |lit: &xla::Literal| -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_literal(None, lit)
                .map_err(|e| anyhow::anyhow!("uploading step arg: {e:?}"))
        };
        let tok_buf = up(&tok_lit)?;
        let len_buf = up(&len_lit)?;
        let kv_buf = up(&state.kv)?;
        let rs_buf = up(&state.rstate)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.weights.len() + 4);
        args.extend(self.weights.iter());
        args.extend([&tok_buf, &len_buf, &kv_buf, &rs_buf]);
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("executing T={t} step: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching step output: {e:?}"))?;
        self.exec_wall_ns += start.elapsed().as_nanos();
        self.exec_calls += 1;

        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing step tuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
        let rstate = parts.pop().unwrap();
        let kv = parts.pop().unwrap();
        let topk_lit = parts.pop().unwrap();
        let logits_lit = parts.pop().unwrap();

        let logits = logits_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("logits to_vec: {e:?}"))?;
        let topk = topk_lit
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("topk to_vec: {e:?}"))?;
        let rstate_seq = rstate
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("rstate to_vec: {e:?}"))?;

        // KV is committed immediately (stale speculative rows get
        // overwritten by construction); the router state is per-token, so
        // the caller commits it at the accepted position via
        // `commit_rstate`.
        state.kv = kv;

        Ok(StepOutput::new(
            logits,
            topk,
            rstate_seq,
            t,
            self.model.mini.vocab,
            self.model.mini.layers,
            self.model.mini.topk_arity(),
            self.model.mini.hidden,
        ))
    }

    /// Commit the router-affinity state after accepting `advance` in-flight
    /// tokens of `out` (i.e. roll back any rejected speculative updates).
    pub fn commit_rstate(
        &self,
        state: &mut RequestState,
        out: &StepOutput,
        advance: usize,
    ) -> Result<()> {
        anyhow::ensure!(advance >= 1 && advance <= out.t, "bad advance {advance}");
        let row = out.rstate_at(advance - 1);
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(row.as_ptr() as *const u8, row.len() * 4)
        };
        state.rstate = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[self.model.mini.layers, self.model.mini.hidden],
            bytes,
        )
        .map_err(|e| anyhow::anyhow!("building rstate literal: {e:?}"))?;
        Ok(())
    }

    /// Average wall time per PJRT execute call (ns).
    pub fn mean_exec_ns(&self) -> f64 {
        if self.exec_calls == 0 {
            0.0
        } else {
            self.exec_wall_ns as f64 / self.exec_calls as f64
        }
    }
}

/// Read `weights.npz` and upload every array to the device, in parameter
/// order (the npz keys are index-prefixed by aot.py, so lexicographic
/// order is parameter order).
fn load_weights(
    client: &xla::PjRtClient,
    model: &Model,
) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
    use xla::FromRawBytes;
    let mut entries = xla::Literal::read_npz(&model.weights_path, &())
        .map_err(|e| anyhow::anyhow!("reading {:?}: {e:?}", model.weights_path))?;
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    anyhow::ensure!(
        entries.len() == model.weights.count,
        "weights.npz has {} arrays, manifest says {}",
        entries.len(),
        model.weights.count
    );
    let buffers = entries
        .iter()
        .map(|(name, lit)| {
            client
                .buffer_from_host_literal(None, lit)
                .map_err(|e| anyhow::anyhow!("uploading weight {name}: {e:?}"))
        })
        .collect::<Result<Vec<_>>>()?;
    // The literals are returned (and stored) because the host->device copy
    // is asynchronous; dropping them early is a use-after-free.
    Ok((buffers, entries.into_iter().map(|(_, l)| l).collect()))
}
