//! Typed view over one step's outputs (logits + router top-k indices).

/// Host-side outputs of a T-token step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Row-major f32[T, V].
    logits: Vec<f32>,
    /// Row-major i32[L, T, Kr]; dense models emit -1 sentinels.
    topk: Vec<i32>,
    /// Row-major f32[L, T, H]: per-token router-state (affinity EMA)
    /// trajectory. The engine commits the row of the last *accepted*
    /// position so rejected drafts cannot pollute future routing.
    pub rstate_seq: Vec<f32>,
    pub t: usize,
    pub vocab: usize,
    pub layers: usize,
    pub topk_arity: usize,
    pub hidden: usize,
}

impl StepOutput {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        logits: Vec<f32>,
        topk: Vec<i32>,
        rstate_seq: Vec<f32>,
        t: usize,
        vocab: usize,
        layers: usize,
        topk_arity: usize,
        hidden: usize,
    ) -> Self {
        debug_assert_eq!(logits.len(), t * vocab);
        debug_assert_eq!(topk.len(), layers * t * topk_arity);
        debug_assert_eq!(rstate_seq.len(), layers * t * hidden);
        Self { logits, topk, rstate_seq, t, vocab, layers, topk_arity, hidden }
    }

    /// Router-state row [L, H] after consuming in-flight token `pos`.
    pub fn rstate_at(&self, pos: usize) -> Vec<f32> {
        debug_assert!(pos < self.t);
        let mut out = Vec::with_capacity(self.layers * self.hidden);
        for l in 0..self.layers {
            let base = (l * self.t + pos) * self.hidden;
            out.extend_from_slice(&self.rstate_seq[base..base + self.hidden]);
        }
        out
    }

    /// Logits row for in-flight token `i` (predicts the token after it).
    pub fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    /// Router top-k expert ids for (layer, token).
    pub fn topk_at(&self, layer: usize, token: usize) -> &[i32] {
        let base = (layer * self.t + token) * self.topk_arity;
        &self.topk[base..base + self.topk_arity]
    }

    /// Unique experts activated per layer across the first `valid` tokens —
    /// the quantity the paper's verification-cost analysis is built on
    /// (§2.4). Dense models (sentinel -1) report 0.
    pub fn unique_experts_per_layer(&self, valid: usize) -> Vec<usize> {
        let valid = valid.min(self.t);
        (0..self.layers)
            .map(|l| {
                let mut seen = [false; 128]; // n_experts <= 64 in the zoo
                let mut count = 0usize;
                for tok in 0..valid {
                    for &e in self.topk_at(l, tok) {
                        if e >= 0 {
                            let idx = e as usize & 127;
                            if !seen[idx] {
                                seen[idx] = true;
                                count += 1;
                            }
                        }
                    }
                }
                count
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StepOutput {
        // T=2, V=4, L=2, Kr=2, H=2
        let logits = vec![
            0.1, 0.9, 0.0, 0.0, // token 0
            0.0, 0.0, 0.7, 0.3, // token 1
        ];
        let topk = vec![
            0, 1, /* l0 t0 */ 1, 2, /* l0 t1 */
            3, 3, /* l1 t0 */ 3, 4, /* l1 t1 */
        ];
        let rstate = vec![
            1.0, 2.0, /* l0 t0 */ 3.0, 4.0, /* l0 t1 */
            5.0, 6.0, /* l1 t0 */ 7.0, 8.0, /* l1 t1 */
        ];
        StepOutput::new(logits, topk, rstate, 2, 4, 2, 2, 2)
    }

    #[test]
    fn logits_rows() {
        let s = sample();
        assert_eq!(s.logits_row(0)[1], 0.9);
        assert_eq!(s.logits_row(1)[2], 0.7);
    }

    #[test]
    fn unique_expert_counts() {
        let s = sample();
        // layer 0: {0,1} ∪ {1,2} = 3; layer 1: {3} ∪ {3,4} = 2
        assert_eq!(s.unique_experts_per_layer(2), vec![3, 2]);
        // only first token valid: layer 0 {0,1}=2, layer 1 {3}=1
        assert_eq!(s.unique_experts_per_layer(1), vec![2, 1]);
    }

    #[test]
    fn dense_sentinels_count_zero() {
        let s = StepOutput::new(vec![0.0; 4], vec![-1, -1], vec![0.0; 4], 1, 4, 2, 1, 2);
        assert_eq!(s.unique_experts_per_layer(1), vec![0, 0]);
    }

    #[test]
    fn rstate_rows_select_position() {
        let s = sample();
        assert_eq!(s.rstate_at(0), vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(s.rstate_at(1), vec![3.0, 4.0, 7.0, 8.0]);
    }
}
