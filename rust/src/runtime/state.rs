//! Per-request device state: functional KV cache + router-affinity state.

use crate::models::MiniConfig;

/// Device-side state threaded through decode steps. The KV cache and router
/// state stay as XLA literals between steps (no host round-trip of the
/// cache contents on the request path).
pub struct RequestState {
    /// f32[L, 2, S, KVD] — keys/values for positions `< cache_len` are
    /// committed; higher positions are speculative scratch.
    pub kv: xla::Literal,
    /// f32[L, H] — per-layer EMA of hidden states (expert-affinity state).
    pub rstate: xla::Literal,
    /// Number of committed cache positions. The next step writes at
    /// `[cache_len, cache_len + T)`.
    pub cache_len: usize,
    /// Capacity (max_seq of the AOT variant).
    pub max_seq: usize,
}

impl RequestState {
    /// Zero-initialized state for a fresh request.
    pub fn fresh(cfg: &MiniConfig) -> Self {
        let kv = xla::Literal::create_from_shape(
            xla::PrimitiveType::F32,
            &[cfg.layers, 2, cfg.max_seq, cfg.kv_dim()],
        );
        let rstate =
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &[cfg.layers, cfg.hidden]);
        Self { kv, rstate, cache_len: 0, max_seq: cfg.max_seq }
    }

    /// Remaining cache capacity in tokens.
    pub fn remaining(&self) -> usize {
        self.max_seq.saturating_sub(self.cache_len)
    }

    /// Whether a T-token step fits in the cache window.
    pub fn fits(&self, t: usize) -> bool {
        self.cache_len + t <= self.max_seq
    }
}
