//! Small deterministic PRNG (splitmix64 + xoshiro256**).
//!
//! Experiments must be bit-reproducible across runs and platforms, so the
//! crate carries its own generator instead of depending on `rand`'s
//! version-dependent streams.

/// Deterministic 64-bit PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample `k` distinct values from `[0, n)` (k <= n).
    pub fn distinct(&mut self, k: usize, n: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let v = self.below(n);
            if !out.contains(&v) {
                out.push(v);
            }
        }
        out
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn distinct_are_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let v = r.distinct(8, 16);
            let mut sorted = v.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
            assert!(v.iter().all(|&x| x < 16));
        }
    }

    #[test]
    fn chance_rate_roughly_correct() {
        let mut r = Rng::new(11);
        let hits = (0..10_000).filter(|_| r.chance(0.3)).count();
        assert!((2700..3300).contains(&hits), "{hits}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
