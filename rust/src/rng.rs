//! Small deterministic PRNG (splitmix64 + xoshiro256**).
//!
//! Experiments must be bit-reproducible across runs and platforms, so the
//! crate carries its own generator instead of depending on `rand`'s
//! version-dependent streams.

/// Deterministic 64-bit PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample `k` distinct values from `[0, n)` (k <= n).
    pub fn distinct(&mut self, k: usize, n: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let v = self.below(n);
            if !out.contains(&v) {
                out.push(v);
            }
        }
        out
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Fill `out` with the next `out.len()` raw draws, in stream order.
    /// `fill_u64` followed by indexing the buffer front-to-back is
    /// bit-identical to the same number of `next_u64` calls — the batched
    /// refill the routing hot path uses via [`BufRng`].
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        for slot in out.iter_mut() {
            *slot = self.next_u64();
        }
    }
}

/// A [`Rng`] with a refillable draw buffer.
///
/// The sim backend's routing loop makes several tiny draws per token per
/// layer; `BufRng` amortises those into one [`Rng::fill_u64`] refill per
/// `capacity` draws while producing the *exact same stream*: every derived
/// draw (`below`, `f64`, `chance`) applies the same arithmetic to the same
/// underlying `next_u64` sequence, so swapping `Rng` for `BufRng` is
/// bit-invisible to every consumer. Proven by `buffered_matches_unbuffered`
/// below for arbitrary buffer sizes.
#[derive(Debug, Clone)]
pub struct BufRng {
    rng: Rng,
    buf: Vec<u64>,
    at: usize,
}

/// Default refill batch: covers a full route_layer worth of draws for the
/// largest top-k in the zoo without over-buffering tiny slots.
const BUF_RNG_CAPACITY: usize = 32;

impl BufRng {
    /// Buffered generator over a fresh stream for `seed`.
    pub fn new(seed: u64) -> Self {
        Self::with_capacity(seed, BUF_RNG_CAPACITY)
    }

    /// Buffered generator with an explicit refill batch size (>= 1).
    /// Exposed so the bit-identity property test can sweep sizes.
    pub fn with_capacity(seed: u64, capacity: usize) -> Self {
        debug_assert!(capacity >= 1);
        Self { rng: Rng::new(seed), buf: vec![0; capacity.max(1)], at: capacity.max(1) }
    }

    /// Reseed in place, discarding any buffered draws. Reuses the buffer
    /// allocation — the per-request reset on the hot path.
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
        self.at = self.buf.len();
    }

    /// Next raw draw, refilling the buffer when drained. Bit-identical to
    /// `Rng::next_u64` on the same seed and call count.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if self.at >= self.buf.len() {
            self.rng.fill_u64(&mut self.buf);
            self.at = 0;
        }
        let v = self.buf[self.at];
        self.at += 1;
        v
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn distinct_are_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let v = r.distinct(8, 16);
            let mut sorted = v.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
            assert!(v.iter().all(|&x| x < 16));
        }
    }

    #[test]
    fn chance_rate_roughly_correct() {
        let mut r = Rng::new(11);
        let hits = (0..10_000).filter(|_| r.chance(0.3)).count();
        assert!((2700..3300).contains(&hits), "{hits}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fill_matches_sequential_draws() {
        let mut a = Rng::new(0xF1FF);
        let mut b = Rng::new(0xF1FF);
        let mut buf = [0u64; 17];
        a.fill_u64(&mut buf);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, b.next_u64(), "draw {i}");
        }
        // The stream continues seamlessly after a fill.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    /// Satellite (a): the buffered sequence is bit-identical to repeated
    /// `next_u64` calls for any buffer size, across every derived draw
    /// shape, including interleavings that drain the buffer mid-pattern.
    #[test]
    fn buffered_matches_unbuffered() {
        for capacity in [1, 2, 3, 5, 7, 13, 32, 81] {
            let mut plain = Rng::new(0xBEEF ^ capacity as u64);
            let mut buffered = BufRng::with_capacity(0xBEEF ^ capacity as u64, capacity);
            for step in 0..500 {
                match step % 4 {
                    0 => assert_eq!(plain.next_u64(), buffered.next_u64(), "cap {capacity}"),
                    1 => assert_eq!(plain.below(7), buffered.below(7), "cap {capacity}"),
                    2 => {
                        let (x, y) = (plain.f64(), buffered.f64());
                        assert!(x == y, "cap {capacity}: {x} != {y}");
                    }
                    _ => assert_eq!(plain.chance(0.4), buffered.chance(0.4), "cap {capacity}"),
                }
            }
        }
    }

    #[test]
    fn reseed_restarts_stream() {
        let mut buffered = BufRng::new(100);
        let first: Vec<u64> = (0..10).map(|_| buffered.next_u64()).collect();
        buffered.reseed(100);
        let again: Vec<u64> = (0..10).map(|_| buffered.next_u64()).collect();
        assert_eq!(first, again);
        let mut plain = Rng::new(100);
        assert_eq!(first, (0..10).map(|_| plain.next_u64()).collect::<Vec<_>>());
    }
}
