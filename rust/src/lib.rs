//! # Cascade: utility-driven speculative decoding for MoE serving
//!
//! A three-layer reproduction of *"Utility-Driven Speculative Decoding for
//! Mixture-of-Experts"* (CS.DC 2025):
//!
//! * **L1/L2** (build time, Python): Pallas kernels + a JAX MoE transformer,
//!   AOT-lowered to HLO text (`make artifacts`). Python never runs on the
//!   request path.
//! * **L3** (this crate): a vLLM-style single-batch serving coordinator —
//!   scheduler, KV-cache manager, drafters, rejection sampler — with the
//!   paper's contribution, the **utility-driven speculation manager**
//!   (test-and-set, adaptive back-off, hill-climbing), as a first-class
//!   policy in [`spec`].
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API and the
//! [`coordinator`] drives them; [`cost`] converts measured expert
//! activations into GPU memory traffic at paper scale (see DESIGN.md §2 for
//! the substitution argument); [`experiments`] regenerates every table and
//! figure in the paper's evaluation.

pub mod analysis;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod experiments;
pub mod kv;
pub mod metrics;
pub mod models;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod sim;
pub mod spec;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use config::{CascadeParams, EngineConfig};
pub use coordinator::batch::BatchEngine;
pub use coordinator::engine::Engine;
pub use spec::policy::{PolicyKind, SpecPolicy};
