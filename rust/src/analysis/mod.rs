//! Repo-native lint suite: tidy-style static analysis over the source tree.
//!
//! Modeled on rust-lang's `src/tools/tidy`: zero-dependency, line/AST-lite
//! passes wired into `cargo test` through `rust/tests/repo_lints.rs`, so
//! the invariants every headline number rests on are machine-checked on
//! every run:
//!
//! * [`determinism`] — no hash-order iteration, no host-clock reads outside
//!   justified wall-telemetry sites (the virtual clock must never read host
//!   time), and no RNG but the crate PRNG ([`crate::rng`]);
//! * [`cost`] — every [`crate::cost::IterCost`] field is conserved through
//!   `total()`, `verify_s()` (or a written exemption), the README cost-law
//!   table, and a telemetry/docs sink;
//! * [`telemetry`] — every metrics field is serialized by at least one
//!   CLI/bench/figure emitter, and every `EngineConfig` field is reachable
//!   from a `main.rs` flag and mentioned in `rust/docs/`;
//! * [`docs`] — relative markdown links in README.md and rust/docs/*.md
//!   resolve to real files;
//! * [`hotpath`] — no tree-set expert collections on the serving hot path
//!   (`sim/`, `coordinator/`, `cost/`): expert sets there are
//!   [`crate::cost::bitmap::ExpertBitmap`] word arrays (rust/docs/perf.md).
//!
//! Violations are suppressible only per line, with a named rule and a
//! written justification (see rust/docs/lints.md for the directive
//! grammar). A blanket, unjustified, or unknown-rule directive is itself a
//! violation (`lint-allow`).

pub mod cost;
pub mod determinism;
pub mod docs;
pub mod hotpath;
pub mod telemetry;

use anyhow::{Context, Result};
use std::fmt;
use std::path::Path;

/// Every rule the suite knows. A suppression directive naming anything
/// else is rejected by the `lint-allow` meta-rule.
pub const KNOWN_RULES: &[&str] = &[
    "hash-collection",
    "wall-clock",
    "foreign-rng",
    "cost-conservation",
    "telemetry-dead-field",
    "config-coverage",
    "doc-links",
    "hot-path-set",
    "lint-allow",
];

/// The suppression token, assembled from pieces so the code that validates
/// directives never mistakes its own source for one.
pub const ALLOW_TOKEN: &str = concat!("lint", ":", "allow");

/// One file of the repo snapshot the rules consult.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Repo-relative path with forward slashes (e.g. `rust/src/kv/mod.rs`).
    pub path: String,
    pub text: String,
}

/// Snapshot of every file the rules consult: crate sources, root markdown,
/// and `rust/docs/*.md`. Loaded from disk by [`load_repo`] for the real
/// run; built inline by the fixture self-tests.
#[derive(Debug, Clone, Default)]
pub struct RepoTree {
    pub files: Vec<SourceFile>,
}

impl RepoTree {
    pub fn get(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }

    /// Crate sources the determinism rules sweep (`rust/src/**/*.rs`).
    pub fn rust_sources(&self) -> impl Iterator<Item = &SourceFile> {
        self.files
            .iter()
            .filter(|f| f.path.starts_with("rust/src/") && f.path.ends_with(".rs"))
    }

    /// The crate's documentation pages (`rust/docs/*.md`).
    pub fn doc_pages(&self) -> impl Iterator<Item = &SourceFile> {
        self.files
            .iter()
            .filter(|f| f.path.starts_with("rust/docs/") && f.path.ends_with(".md"))
    }
}

/// One finding, carrying everything the failure report needs: the rule,
/// the file, the line (1-based; 0 for file-level findings such as a
/// missing sink), and a message naming what is broken and how to fix it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(f, "[{}] {}: {}", self.rule, self.path, self.msg)
        } else {
            write!(f, "[{}] {}:{}: {}", self.rule, self.path, self.line, self.msg)
        }
    }
}

/// Load the repo snapshot from disk. `root` is the repository root (the
/// parent of `rust/`): root-level `*.md`, `rust/docs/*.md`, and
/// `rust/src/**/*.rs` are read; everything else (vendor trees, artifacts,
/// target/) stays out of scope.
pub fn load_repo(root: &Path) -> Result<RepoTree> {
    let mut files = Vec::new();
    push_dir(root, &root.join("rust/src"), &mut files, "rs")?;
    push_dir(root, &root.join("rust/docs"), &mut files, "md")?;
    for entry in std::fs::read_dir(root).with_context(|| format!("reading {root:?}"))? {
        let path = entry?.path();
        if path.is_file() && path.extension().and_then(|e| e.to_str()) == Some("md") {
            push_file(root, &path, &mut files)?;
        }
    }
    files.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(RepoTree { files })
}

fn push_dir(root: &Path, dir: &Path, out: &mut Vec<SourceFile>, ext: &str) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {dir:?}"))? {
        let path = entry?.path();
        if path.is_dir() {
            push_dir(root, &path, out, ext)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some(ext) {
            push_file(root, &path, out)?;
        }
    }
    Ok(())
}

fn push_file(root: &Path, path: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    let rel = path.strip_prefix(root).unwrap_or(path).to_string_lossy().replace('\\', "/");
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    out.push(SourceFile { path: rel, text });
    Ok(())
}

/// Run every rule over the tree; findings come back sorted by
/// (path, line, rule) so the report is stable.
pub fn run_all(tree: &RepoTree) -> Vec<Violation> {
    let mut v = Vec::new();
    determinism::check(tree, &mut v);
    check_allow_directives(tree, &mut v);
    cost::check(tree, &mut v);
    telemetry::check(tree, &mut v);
    docs::check(tree, &mut v);
    hotpath::check(tree, &mut v);
    v.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    v
}

/// Render findings for the failing test's panic message.
pub fn report(violations: &[Violation]) -> String {
    let mut s = String::new();
    for v in violations {
        s.push_str(&v.to_string());
        s.push('\n');
    }
    s.push_str(&format!("{} repo-lint violation(s)", violations.len()));
    s
}

// ---- Suppression directives ---------------------------------------------

/// Parse a suppression directive out of one raw source line.
///
/// * `None` — the line carries no directive;
/// * `Some(Ok((rule, justification)))` — a well-formed directive;
/// * `Some(Err(msg))` — a directive that must be rejected: blanket (no
///   rule named), unknown rule, or missing/empty justification.
pub fn parse_allow(line: &str) -> Option<std::result::Result<(&str, &str), String>> {
    let at = line.find(ALLOW_TOKEN)?;
    let rest = &line[at + ALLOW_TOKEN.len()..];
    let Some(rest) = rest.strip_prefix('(') else {
        return Some(Err(
            "blanket allow: a rule name in parentheses is required".to_string()
        ));
    };
    let Some(close) = rest.find(')') else {
        return Some(Err("unterminated rule name in allow directive".to_string()));
    };
    let rule = rest[..close].trim();
    if rule.is_empty() {
        return Some(Err("blanket allow: empty rule name".to_string()));
    }
    if !KNOWN_RULES.contains(&rule) {
        return Some(Err(format!("allow names unknown rule {rule:?}")));
    }
    let Some(why) = rest[close + 1..].trim_start().strip_prefix(':') else {
        return Some(Err(format!(
            "unjustified allow for {rule:?}: a `: <reason>` clause is required"
        )));
    };
    let why = why.trim();
    if why.len() < 8 {
        return Some(Err(format!(
            "allow for {rule:?} needs a written justification, not {why:?}"
        )));
    }
    Some(Ok((rule, why)))
}

/// Is a violation of `rule` at 0-based line index `idx` suppressed? A
/// well-formed directive counts on the offending line itself or on the
/// line directly above it — never file- or block-wide.
pub fn allowed(lines: &[&str], idx: usize, rule: &str) -> bool {
    let hit = |i: usize| matches!(parse_allow(lines[i]), Some(Ok((r, _))) if r == rule);
    hit(idx) || (idx > 0 && hit(idx - 1))
}

/// The meta-rule: every suppression directive in crate sources must be
/// well-formed. Blanket or unjustified allows are violations themselves,
/// so suppression can never silently widen.
fn check_allow_directives(tree: &RepoTree, out: &mut Vec<Violation>) {
    for file in tree.rust_sources() {
        for (i, line) in file.text.lines().enumerate() {
            if let Some(Err(msg)) = parse_allow(line) {
                out.push(Violation {
                    rule: "lint-allow",
                    path: file.path.clone(),
                    line: i + 1,
                    msg,
                });
            }
        }
    }
}

// ---- AST-lite parsing helpers -------------------------------------------

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// The code portion of one source line: everything before a `//` comment
/// start, with enough string/char-literal awareness that a `"//"` inside a
/// string does not truncate the line. AST-lite by design; block comments
/// and raw strings are not handled (the crate style avoids both on lines
/// the rules care about).
pub fn code_portion(line: &str) -> &str {
    let b = line.as_bytes();
    let mut i = 0;
    let mut in_str = false;
    while i < b.len() {
        match b[i] {
            b'\\' if in_str => i += 1,
            b'"' => in_str = !in_str,
            b'\'' if !in_str => {
                // Char literal ('x', '\n') vs lifetime ('a in &'a str): a
                // closing quote within the next 4 bytes means char literal.
                if let Some(rel) = b[i + 1..].iter().take(4).position(|&c| c == b'\'') {
                    i += rel + 1;
                }
            }
            b'/' if !in_str && i + 1 < b.len() && b[i + 1] == b'/' => {
                return &line[..i];
            }
            _ => {}
        }
        i += 1;
    }
    line
}

/// Substring search requiring identifier boundaries wherever the needle
/// itself starts/ends with an identifier character (so `Instant` never
/// matches inside `MyInstant`, while a needle ending in `::` matches the
/// start of any path).
pub fn find_word(hay: &str, needle: &str) -> Option<usize> {
    let h = hay.as_bytes();
    let n = needle.as_bytes();
    if n.is_empty() || h.len() < n.len() {
        return None;
    }
    let need_pre = is_ident(n[0]);
    let need_post = is_ident(n[n.len() - 1]);
    let mut from = 0;
    while let Some(rel) = hay[from..].find(needle) {
        let at = from + rel;
        let end = at + n.len();
        let pre_ok = !need_pre || at == 0 || !is_ident(h[at - 1]);
        let post_ok = !need_post || end >= h.len() || !is_ident(h[end]);
        if pre_ok && post_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

pub fn contains_word(hay: &str, needle: &str) -> bool {
    find_word(hay, needle).is_some()
}

/// The `{ ... }` body (exclusive of the outer braces) opening at byte
/// `open` (which must index a `{`), found by brace counting. `None` when
/// unbalanced.
fn brace_body(text: &str, open: usize) -> Option<&str> {
    let b = text.as_bytes();
    debug_assert_eq!(b.get(open), Some(&b'{'));
    let mut depth = 0usize;
    for (i, &c) in b.iter().enumerate().skip(open) {
        match c {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&text[open + 1..i]);
                }
            }
            _ => {}
        }
    }
    None
}

/// Body of the first `fn name(...)` definition in `text` (AST-lite: the
/// declaration must start its line, the crate style).
pub fn fn_body<'a>(text: &'a str, name: &str) -> Option<&'a str> {
    let pat = format!("fn {name}(");
    let mut offset = 0usize;
    for line in text.lines() {
        let t = line.trim_start();
        let is_decl = t.starts_with(&pat)
            || (t.starts_with("pub ") && t[4..].trim_start().starts_with(&pat));
        if is_decl {
            let open = offset + text[offset..].find('{')?;
            return brace_body(text, open);
        }
        offset += line.len() + 1;
    }
    None
}

/// `(name, body)` of every `pub fn` defined in `text` — duplicates (same
/// method name on different impl blocks) are all returned.
pub fn pub_fn_bodies(text: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut offset = 0usize;
    for line in text.lines() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("pub fn ") {
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                if let Some(open) = text[offset..].find('{').map(|i| offset + i) {
                    if let Some(body) = brace_body(text, open) {
                        out.push((name, body.to_string()));
                    }
                }
            }
        }
        offset += line.len() + 1;
    }
    out
}

/// Field names of `pub struct <name> { ... }` in `text` (AST-lite: one
/// `pub field: Type,` per line, the crate style).
pub fn struct_fields(text: &str, name: &str) -> Vec<String> {
    let mut offset = 0usize;
    let mut decl_at = None;
    for line in text.lines() {
        let t = line.trim_start();
        if (t.starts_with("pub struct ") || t.starts_with("struct "))
            && contains_word(t, name)
        {
            decl_at = Some(offset);
            break;
        }
        offset += line.len() + 1;
    }
    let Some(at) = decl_at else { return Vec::new() };
    let Some(open) = text[at..].find('{').map(|i| at + i) else { return Vec::new() };
    let Some(body) = brace_body(text, open) else { return Vec::new() };
    let mut fields = Vec::new();
    for line in body.lines() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("pub ") {
            if let Some(colon) = rest.find(':') {
                let ident = rest[..colon].trim();
                if !ident.is_empty() && ident.bytes().all(is_ident) {
                    fields.push(ident.to_string());
                }
            }
        }
    }
    fields
}

/// 1-based declaration line of `pub <field>: ...` in `text`, or 0 when
/// not found (good enough for pointing a violation at its field).
pub fn field_decl_line(text: &str, field: &str) -> usize {
    for (i, line) in text.lines().enumerate() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("pub ") {
            if rest.starts_with(field) && rest[field.len()..].trim_start().starts_with(':') {
                return i + 1;
            }
        }
    }
    0
}

/// Every `self.method()` call name in a function body (for one-level
/// inlining of cost helpers).
pub fn self_method_calls(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    for chunk in body.split("self.").skip(1) {
        let ident: String = chunk
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !ident.is_empty() && chunk[ident.len()..].starts_with("()") && !out.contains(&ident)
        {
            out.push(ident);
        }
    }
    out
}

/// Text before the first `#[cfg(test)]` marker — the part of a module that
/// ships, which is what the telemetry/cost sinks must live in.
pub fn non_test_region(text: &str) -> &str {
    match text.find("#[cfg(test)]") {
        Some(at) => &text[..at],
        None => text,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_portion_strips_comments_not_strings() {
        assert_eq!(code_portion("let x = 1; // trailing"), "let x = 1; ");
        assert_eq!(code_portion("let s = \"a // b\";"), "let s = \"a // b\";");
        assert_eq!(code_portion("// whole line"), "");
        assert_eq!(code_portion("let c = '\"'; // after char"), "let c = '\"'; ");
        assert_eq!(code_portion("fn f<'a>(x: &'a str) {} // c"), "fn f<'a>(x: &'a str) {} ");
    }

    #[test]
    fn find_word_respects_ident_boundaries() {
        assert!(contains_word("let m = Foo::new();", "Foo"));
        assert!(!contains_word("let m = MyFoo::new();", "Foo"));
        assert!(!contains_word("let m = Foos::new();", "Foo"));
        // A needle ending in punctuation matches the start of any path.
        assert!(contains_word("bar::baz()", "bar::"));
        assert!(!contains_word("rebar::baz()", "bar::"));
    }

    #[test]
    fn parse_allow_accepts_wellformed_rejects_malformed() {
        let good = format!("let x = 1; // {ALLOW_TOKEN}(wall-clock): host telemetry only");
        assert!(matches!(parse_allow(&good), Some(Ok(("wall-clock", _)))));
        assert!(parse_allow("let x = 1; // plain comment").is_none());

        let blanket = format!("// {ALLOW_TOKEN}: because");
        assert!(matches!(parse_allow(&blanket), Some(Err(_))));
        let unknown = format!("// {ALLOW_TOKEN}(no-such-rule): reasonable words");
        assert!(matches!(parse_allow(&unknown), Some(Err(_))));
        let unjustified = format!("// {ALLOW_TOKEN}(wall-clock)");
        assert!(matches!(parse_allow(&unjustified), Some(Err(_))));
        let short = format!("// {ALLOW_TOKEN}(wall-clock): ok");
        assert!(matches!(parse_allow(&short), Some(Err(_))));
    }

    #[test]
    fn allowed_covers_same_line_and_line_above() {
        let above = format!("// {ALLOW_TOKEN}(foreign-rng): fixture needs it");
        let lines = vec![above.as_str(), "offending line", "unrelated"];
        assert!(allowed(&lines, 1, "foreign-rng"));
        assert!(!allowed(&lines, 1, "wall-clock"));
        assert!(!allowed(&lines, 2, "foreign-rng"));
    }

    #[test]
    fn malformed_allow_is_flagged_by_meta_rule() {
        let text = format!("fn f() {{}}\n// {ALLOW_TOKEN}: everything\n");
        let tree = RepoTree {
            files: vec![SourceFile { path: "rust/src/x.rs".into(), text }],
        };
        let mut v = Vec::new();
        check_allow_directives(&tree, &mut v);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "lint-allow");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn struct_and_fn_parsers_read_crate_style() {
        let src = "/// doc\npub struct Thing {\n    /// doc\n    pub a: f64,\n    pub b_x: usize,\n    private: u8,\n}\n\nimpl Thing {\n    pub fn total(&self) -> f64 {\n        self.a + self.helper()\n    }\n\n    pub fn helper(&self) -> f64 {\n        self.b_x as f64\n    }\n}\n";
        assert_eq!(struct_fields(src, "Thing"), vec!["a".to_string(), "b_x".to_string()]);
        let body = fn_body(src, "total").unwrap();
        assert!(body.contains("self.a"));
        assert_eq!(self_method_calls(body), vec!["helper".to_string()]);
        let names: Vec<String> = pub_fn_bodies(src).into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["total".to_string(), "helper".to_string()]);
        assert_eq!(field_decl_line(src, "b_x"), 5);
    }

    #[test]
    fn violations_render_rule_file_line() {
        let v = Violation {
            rule: "wall-clock",
            path: "rust/src/x.rs".into(),
            line: 7,
            msg: "nope".into(),
        };
        assert_eq!(v.to_string(), "[wall-clock] rust/src/x.rs:7: nope");
    }
}
