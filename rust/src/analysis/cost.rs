//! Cost-conservation lint.
//!
//! The paper's contribution is a cost/benefit metric, so a cost component
//! that silently leaks out of the accounting is the worst bug class this
//! repo can ship. PRs 2–4 each added an `IterCost` field and each had to
//! *remember* to thread it through `total()`, `verify_s()`, the README
//! cost-law table, and telemetry/docs. This rule makes forgetting
//! impossible: every field of [`crate::cost::IterCost`] must be
//!
//! 1. referenced in `total()` (directly or through a one-level
//!    `self.helper()` — how `draft_s` flows via `exposed_draft_s()`),
//! 2. referenced in `verify_s()` **or** carried in [`VERIFY_EXEMPT`] with
//!    a written reason (and the exemption must not go stale),
//! 3. named in the README cost-law table, and
//! 4. visible to users: referenced by `metrics/mod.rs` (non-test region)
//!    or described in `rust/docs/*.md`.
//!
//! Failures name the missing sink, so the fix is mechanical.

use super::{
    contains_word, field_decl_line, fn_body, non_test_region, self_method_calls,
    struct_fields, RepoTree, Violation,
};

pub const COST_PATH: &str = "rust/src/cost/mod.rs";
pub const METRICS_PATH: &str = "rust/src/metrics/mod.rs";
pub const README_PATH: &str = "README.md";

/// Fields legitimately absent from `verify_s()`, each with the reason the
/// exemption is sound. A field that later *does* appear in `verify_s()`
/// must drop its entry here (the stale-exemption check below).
pub const VERIFY_EXEMPT: &[(&str, &str)] = &[
    ("draft_s", "drafting is not verify work; it is charged via exposed_draft_s() in total()"),
    ("draft_hidden_s", "pipeline-overlap bookkeeping inside exposed_draft_s(), not verify"),
    ("reject_s", "rejection sampling runs after the verify step returns"),
    ("reprefill_s", "re-prefill of evicted context happens outside the fused verify"),
    ("stall_s", "injected-stall retries waste wall time around the verify, not inside it"),
    ("migration_s", "self-healing expert movement rides the interconnect beside the verify"),
];

pub fn check(tree: &RepoTree, out: &mut Vec<Violation>) {
    let Some(cost_file) = tree.get(COST_PATH) else {
        out.push(file_level(COST_PATH, "file not found in repo snapshot"));
        return;
    };
    let fields = struct_fields(&cost_file.text, "IterCost");
    if fields.is_empty() {
        out.push(file_level(COST_PATH, "could not parse the IterCost struct"));
        return;
    }
    let total = inlined_body(&cost_file.text, "total");
    let verify = inlined_body(&cost_file.text, "verify_s");
    let readme = tree.get(README_PATH).map(|f| f.text.as_str()).unwrap_or("");
    let metrics = tree.get(METRICS_PATH).map(|f| non_test_region(&f.text)).unwrap_or("");
    let docs_text: String = tree
        .doc_pages()
        .map(|f| f.text.as_str())
        .collect::<Vec<_>>()
        .join("\n");

    for f in &fields {
        let line = field_decl_line(&cost_file.text, f);
        let mut missing: Vec<String> = Vec::new();
        if !contains_word(&total, f) {
            missing.push(
                "total() — every cost component must reach the iteration total".to_string(),
            );
        }
        let in_verify = contains_word(&verify, f);
        let exempt = VERIFY_EXEMPT.iter().any(|(n, _)| *n == f.as_str());
        if !in_verify && !exempt {
            missing.push(
                "verify_s() — add the term, or an analysis::cost::VERIFY_EXEMPT entry \
                 with a written reason"
                    .to_string(),
            );
        }
        if in_verify && exempt {
            missing.push(format!(
                "stale exemption — `{f}` appears in verify_s(); drop its VERIFY_EXEMPT \
                 entry"
            ));
        }
        if !contains_word(readme, f) {
            missing.push("README.md cost-law table — name the field there".to_string());
        }
        if !contains_word(metrics, f) && !contains_word(&docs_text, f) {
            missing.push(
                "telemetry/docs — reference it in metrics/mod.rs or describe it in \
                 rust/docs/*.md"
                    .to_string(),
            );
        }
        for sink in missing {
            out.push(Violation {
                rule: "cost-conservation",
                path: COST_PATH.to_string(),
                line,
                msg: format!("IterCost field `{f}` missing sink: {sink}"),
            });
        }
    }
}

/// Body of `fn name` with every directly-called `self.helper()` body
/// appended — one level of inlining, enough to see `draft_s` reach
/// `total()` through `exposed_draft_s()`.
fn inlined_body(text: &str, name: &str) -> String {
    let mut body = fn_body(text, name).unwrap_or("").to_string();
    let calls = self_method_calls(&body);
    for callee in calls {
        if let Some(b) = fn_body(text, &callee) {
            body.push('\n');
            body.push_str(b);
        }
    }
    body
}

fn file_level(path: &str, msg: &str) -> Violation {
    Violation {
        rule: "cost-conservation",
        path: path.to_string(),
        line: 0,
        msg: msg.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::SourceFile;

    /// Fixture tree: a two-field IterCost (one verify term, one exempt
    /// field) plus every sink file.
    fn tree(total_terms: &str, verify_terms: &str, readme: &str, metrics: &str) -> RepoTree {
        let cost = format!(
            "pub struct IterCost {{\n    pub a_s: f64,\n    pub reprefill_s: f64,\n}}\n\n\
             impl IterCost {{\n    pub fn total(&self) -> f64 {{\n        {total_terms}\n    \
             }}\n\n    pub fn verify_s(&self) -> f64 {{\n        {verify_terms}\n    }}\n}}\n"
        );
        RepoTree {
            files: vec![
                SourceFile { path: COST_PATH.into(), text: cost },
                SourceFile { path: README_PATH.into(), text: readme.to_string() },
                SourceFile { path: METRICS_PATH.into(), text: metrics.to_string() },
            ],
        }
    }

    fn run(tree: &RepoTree) -> Vec<Violation> {
        let mut v = Vec::new();
        check(tree, &mut v);
        v
    }

    #[test]
    fn conserved_fields_pass() {
        let t = tree(
            "self.a_s + self.reprefill_s",
            "self.a_s",
            "| a_s | reprefill_s |",
            "fn x(c: &IterCost) -> f64 { c.a_s + c.reprefill_s }",
        );
        let v = run(&t);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn field_absent_from_total_names_the_sink() {
        let t = tree(
            "self.a_s",
            "self.a_s",
            "| a_s | reprefill_s |",
            "fn x(c: &IterCost) -> f64 { c.a_s + c.reprefill_s }",
        );
        let v = run(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "cost-conservation");
        assert!(v[0].msg.contains("reprefill_s") && v[0].msg.contains("total()"), "{}", v[0]);
        assert_eq!(v[0].line, 3); // the field's declaration line
    }

    #[test]
    fn non_exempt_field_must_reach_verify() {
        // a_s is not in VERIFY_EXEMPT, so dropping it from verify_s fails.
        let t = tree(
            "self.a_s + self.reprefill_s",
            "self.reprefill_s + 0.0",
            "| a_s | reprefill_s |",
            "fn x(c: &IterCost) -> f64 { c.a_s + c.reprefill_s }",
        );
        let v = run(&t);
        let msgs: Vec<String> = v.iter().map(|v| v.msg.clone()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("`a_s`") && m.contains("verify_s()")),
            "{msgs:?}"
        );
        // ... and reprefill_s showing up in verify_s makes its exemption
        // stale.
        assert!(
            msgs.iter().any(|m| m.contains("`reprefill_s`") && m.contains("stale")),
            "{msgs:?}"
        );
    }

    #[test]
    fn readme_and_docs_sinks_are_checked() {
        let t = tree(
            "self.a_s + self.reprefill_s",
            "self.a_s",
            "cost table without the field names",
            "fn x() {}",
        );
        let v = run(&t);
        let msgs: Vec<String> = v.iter().map(|v| v.msg.clone()).collect();
        assert!(msgs.iter().any(|m| m.contains("README")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("telemetry/docs")), "{msgs:?}");
    }

    #[test]
    fn helper_indirection_counts_for_total() {
        // a_s flows into total() only through a helper — one-level
        // inlining must see it.
        let cost = "pub struct IterCost {\n    pub a_s: f64,\n    pub reprefill_s: f64,\n}\n\n\
                    impl IterCost {\n    pub fn total(&self) -> f64 {\n        \
                    self.helper() + self.reprefill_s\n    }\n\n    pub fn helper(&self) -> \
                    f64 {\n        self.a_s\n    }\n\n    pub fn verify_s(&self) -> f64 {\n        \
                    self.a_s\n    }\n}\n";
        let t = RepoTree {
            files: vec![
                SourceFile { path: COST_PATH.into(), text: cost.to_string() },
                SourceFile { path: README_PATH.into(), text: "a_s reprefill_s".into() },
                SourceFile {
                    path: METRICS_PATH.into(),
                    text: "fn x(c: &IterCost) -> f64 { c.a_s + c.reprefill_s }".into(),
                },
            ],
        };
        let v = run(&t);
        assert!(v.is_empty(), "{v:?}");
    }
}
