//! Doc-integrity lint: relative markdown links resolve.
//!
//! The README and `rust/docs/*.md` cross-link heavily (every cost-law row
//! points at the doc that derives it), and a renamed file silently strands
//! readers. This rule extracts every inline `](target)` link from
//! `README.md` and `rust/docs/*.md`, skips absolute/external targets
//! (`http…`, `#…`, `mailto:`), resolves the rest against the linking
//! file's directory, and requires the target to exist in the repo
//! snapshot. A `..` escaping the repository root is its own finding.

use super::{RepoTree, SourceFile, Violation};

pub fn check(tree: &RepoTree, out: &mut Vec<Violation>) {
    for file in &tree.files {
        let in_scope = file.path == "README.md"
            || (file.path.starts_with("rust/docs/") && file.path.ends_with(".md"));
        if in_scope {
            check_file(tree, file, out);
        }
    }
}

pub fn check_file(tree: &RepoTree, file: &SourceFile, out: &mut Vec<Violation>) {
    let dir = match file.path.rfind('/') {
        Some(i) => &file.path[..i],
        None => "",
    };
    for (i, line) in file.text.lines().enumerate() {
        for target in link_targets(line) {
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with('#')
                || target.starts_with("mailto:")
            {
                continue;
            }
            let path_part = target.split('#').next().unwrap_or("");
            if path_part.is_empty() {
                continue;
            }
            match resolve(dir, path_part) {
                Some(resolved) if tree.get(&resolved).is_some() => {}
                Some(resolved) => out.push(Violation {
                    rule: "doc-links",
                    path: file.path.clone(),
                    line: i + 1,
                    msg: format!("broken relative link `{target}` (resolves to `{resolved}`)"),
                }),
                None => out.push(Violation {
                    rule: "doc-links",
                    path: file.path.clone(),
                    line: i + 1,
                    msg: format!("link `{target}` escapes the repository root"),
                }),
            }
        }
    }
}

/// Every inline-link target (`](target)`) on one line.
fn link_targets(line: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut rest = line;
    while let Some(at) = rest.find("](") {
        let tail = &rest[at + 2..];
        match tail.find(')') {
            Some(end) => {
                out.push(&tail[..end]);
                rest = &tail[end + 1..];
            }
            None => break,
        }
    }
    out
}

/// Normalize `target` relative to `dir` (forward-slash paths); `None`
/// when a `..` segment climbs past the repository root.
fn resolve(dir: &str, target: &str) -> Option<String> {
    let mut parts: Vec<&str> =
        if dir.is_empty() { Vec::new() } else { dir.split('/').collect() };
    for seg in target.split('/') {
        match seg {
            "" | "." => {}
            ".." => {
                parts.pop()?;
            }
            s => parts.push(s),
        }
    }
    Some(parts.join("/"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree() -> RepoTree {
        RepoTree {
            files: vec![
                SourceFile {
                    path: "README.md".into(),
                    text: "see [docs](rust/docs/a.md) and [site](https://example.com)\n"
                        .into(),
                },
                SourceFile {
                    path: "rust/docs/a.md".into(),
                    text: "back to the [README](../../README.md)\n".into(),
                },
            ],
        }
    }

    fn run(t: &RepoTree) -> Vec<Violation> {
        let mut v = Vec::new();
        check(t, &mut v);
        v
    }

    #[test]
    fn resolving_links_pass() {
        let t = tree();
        let v = run(&t);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn broken_link_names_file_line_and_target() {
        let mut t = tree();
        t.files[0].text.push_str("and a [gone](rust/docs/missing.md) link\n");
        let v = run(&t);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "doc-links");
        assert_eq!((v[0].path.as_str(), v[0].line), ("README.md", 2));
        assert!(v[0].msg.contains("rust/docs/missing.md"), "{}", v[0]);
    }

    #[test]
    fn dotdot_resolution_and_root_escape() {
        let mut t = tree();
        t.files[1].text.push_str("escape [up](../../../outside.md)\n");
        let v = run(&t);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("escapes"), "{}", v[0]);
        assert_eq!(v[0].path, "rust/docs/a.md");
    }

    #[test]
    fn fragments_and_anchors_are_tolerated() {
        let mut t = tree();
        t.files[0].text.push_str("[sec](rust/docs/a.md#anchor) [self](#local)\n");
        let v = run(&t);
        assert!(v.is_empty(), "{v:?}");
    }
}
