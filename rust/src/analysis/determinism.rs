//! Determinism lints.
//!
//! Every losslessness guarantee in `rust/tests/` — bit-exact token
//! streams across eviction, sharding, pipelining, and arrival replay —
//! rests on the serving stack being a pure function of (config, seed).
//! Three source-level invariants keep it that way, each enforced as a
//! line rule over `rust/src/**/*.rs`:
//!
//! * **hash-collection** — no hash-map/set types: their iteration order
//!   is randomized per process, so any aggregate built by iterating one
//!   can differ between identical-seed runs. Use BTree types (or sort
//!   before iterating).
//! * **wall-clock** — no host-clock reads: the virtual clock (simulated
//!   seconds) must never observe host time. Host-wall *telemetry* (e.g.
//!   drafter wall-time measurement) is legitimate and carries a justified
//!   per-line allow.
//! * **foreign-rng** — no RNG but the crate PRNG ([`crate::rng`]): its
//!   streams are bit-stable across platforms and versions; any other
//!   source of randomness is not.

use super::{allowed, code_portion, contains_word, RepoTree, SourceFile, Violation};

struct LineRule {
    rule: &'static str,
    /// Banned tokens, assembled from pieces so this file never flags
    /// itself.
    needles: &'static [&'static str],
    why: &'static str,
}

const LINE_RULES: &[LineRule] = &[
    LineRule {
        rule: "hash-collection",
        needles: &[concat!("Hash", "Map"), concat!("Hash", "Set")],
        why: "hash iteration order is nondeterministic; use BTreeMap/BTreeSet or sort \
              before iterating",
    },
    LineRule {
        rule: "wall-clock",
        needles: &[concat!("Instant", "::now"), concat!("System", "Time")],
        why: "the virtual clock must never read host time; wall-telemetry sites need a \
              justified per-line allow",
    },
    LineRule {
        rule: "foreign-rng",
        needles: &[
            concat!("rand", "::"),
            concat!("thread", "_rng"),
            concat!("Std", "Rng"),
            concat!("Small", "Rng"),
            concat!("get", "random"),
            concat!("Random", "State"),
        ],
        why: "all randomness must flow through the crate PRNG (rng.rs) so streams stay \
              bit-reproducible",
    },
];

/// Sweep every crate source.
pub fn check(tree: &RepoTree, out: &mut Vec<Violation>) {
    for file in tree.rust_sources() {
        check_file(file, out);
    }
}

/// Line sweep over one file (the fixture self-tests drive this directly).
pub fn check_file(file: &SourceFile, out: &mut Vec<Violation>) {
    let lines: Vec<&str> = file.text.lines().collect();
    for (i, raw) in lines.iter().enumerate() {
        let code = code_portion(raw);
        for rule in LINE_RULES {
            for needle in rule.needles {
                if contains_word(code, needle) && !allowed(&lines, i, rule.rule) {
                    out.push(Violation {
                        rule: rule.rule,
                        path: file.path.clone(),
                        line: i + 1,
                        msg: format!("`{needle}`: {}", rule.why),
                    });
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ALLOW_TOKEN;

    fn sweep(text: String) -> Vec<Violation> {
        let file = SourceFile { path: "rust/src/fixture.rs".into(), text };
        let mut out = Vec::new();
        check_file(&file, &mut out);
        out
    }

    #[test]
    fn clean_source_passes() {
        let v = sweep(
            "use std::collections::BTreeMap;\nfn f() { let m: BTreeMap<u32, u32> = \
             BTreeMap::new(); }\n"
                .to_string(),
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn hash_collection_flagged_with_file_and_line() {
        let ty = concat!("Hash", "Map");
        let v = sweep(format!("fn f() {{\n    let m = std::collections::{ty}::new();\n}}\n"));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "hash-collection");
        assert_eq!((v[0].path.as_str(), v[0].line), ("rust/src/fixture.rs", 2));
    }

    #[test]
    fn wall_clock_flagged_unless_allowed() {
        let call = concat!("Instant", "::now");
        let v = sweep(format!("fn f() {{ let t = std::time::{call}(); }}\n"));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "wall-clock");

        let v = sweep(format!(
            "fn f() {{\n    // {ALLOW_TOKEN}(wall-clock): host telemetry, never the \
             virtual clock\n    let t = std::time::{call}();\n}}\n"
        ));
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let call = concat!("Instant", "::now");
        let v = sweep(format!(
            "fn f() {{ let t = std::time::{call}(); // {ALLOW_TOKEN}(foreign-rng): \
             wrong rule named here }}\n"
        ));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "wall-clock");
    }

    #[test]
    fn foreign_rng_flagged() {
        let path = concat!("rand", "::");
        let v = sweep(format!("fn f() {{ let x = {path}random::<u64>(); }}\n"));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "foreign-rng");
    }

    #[test]
    fn banned_token_in_comment_is_ignored() {
        let ty = concat!("Hash", "Map");
        let v = sweep(format!("fn f() {{}} // a {ty} would be bad here\n"));
        assert!(v.is_empty(), "{v:?}");
    }
}
