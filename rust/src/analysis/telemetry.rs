//! Telemetry- and config-completeness lints.
//!
//! **telemetry-dead-field** — every field of `BatchIterRecord`,
//! `BatchRunMetrics`, and `RunMetrics` must be serialized by at least one
//! emitter (the CLI in `main.rs`, the bench harness, or a figure runner in
//! `experiments/`). A field is live when an emitter names it directly, or
//! names a metrics method whose body reads it (the usual path: field →
//! aggregator → table row / JSON key). Recording telemetry nobody can see
//! is how instrumentation rots.
//!
//! **config-coverage** — every `EngineConfig` field must be reachable from
//! a `main.rs` flag (named somewhere in its code) and mentioned in
//! `rust/docs/*.md`, so no knob is ever CLI-invisible or undocumented.

use super::{
    code_portion, contains_word, field_decl_line, non_test_region, pub_fn_bodies,
    struct_fields, RepoTree, Violation,
};

pub const METRICS_PATH: &str = "rust/src/metrics/mod.rs";
pub const CONFIG_PATH: &str = "rust/src/config.rs";
pub const MAIN_PATH: &str = "rust/src/main.rs";

/// The metrics structs whose fields must all be emitted somewhere.
const METRIC_STRUCTS: &[&str] = &["BatchIterRecord", "BatchRunMetrics", "RunMetrics"];

pub fn check(tree: &RepoTree, out: &mut Vec<Violation>) {
    check_metrics(tree, out);
    check_config(tree, out);
}

/// Comment-stripped text of every emitter file.
fn emitter_text(tree: &RepoTree) -> String {
    let mut s = String::new();
    for f in &tree.files {
        let is_emitter = f.path == MAIN_PATH
            || f.path == "rust/src/bench.rs"
            || f.path.starts_with("rust/src/experiments/");
        if is_emitter {
            for line in f.text.lines() {
                s.push_str(code_portion(line));
                s.push('\n');
            }
        }
    }
    s
}

fn check_metrics(tree: &RepoTree, out: &mut Vec<Violation>) {
    let Some(metrics) = tree.get(METRICS_PATH) else {
        out.push(missing_file("telemetry-dead-field", METRICS_PATH));
        return;
    };
    let emitters = emitter_text(tree);
    let src = non_test_region(&metrics.text);
    let methods = pub_fn_bodies(src);
    for st in METRIC_STRUCTS {
        let fields = struct_fields(src, st);
        if fields.is_empty() {
            out.push(Violation {
                rule: "telemetry-dead-field",
                path: METRICS_PATH.to_string(),
                line: 0,
                msg: format!("could not parse struct {st}"),
            });
            continue;
        }
        for f in &fields {
            let direct = contains_word(&emitters, f);
            let via_method = methods
                .iter()
                .any(|(name, body)| contains_word(body, f) && contains_word(&emitters, name));
            if !direct && !via_method {
                out.push(Violation {
                    rule: "telemetry-dead-field",
                    path: METRICS_PATH.to_string(),
                    line: field_decl_line(src, f),
                    msg: format!(
                        "{st} field `{f}` is recorded but never serialized: no CLI/bench/\
                         figure emitter reads it, directly or through an aggregator method"
                    ),
                });
            }
        }
    }
}

fn check_config(tree: &RepoTree, out: &mut Vec<Violation>) {
    let Some(config) = tree.get(CONFIG_PATH) else {
        out.push(missing_file("config-coverage", CONFIG_PATH));
        return;
    };
    let Some(main) = tree.get(MAIN_PATH) else {
        out.push(missing_file("config-coverage", MAIN_PATH));
        return;
    };
    let fields = struct_fields(non_test_region(&config.text), "EngineConfig");
    if fields.is_empty() {
        out.push(Violation {
            rule: "config-coverage",
            path: CONFIG_PATH.to_string(),
            line: 0,
            msg: "could not parse struct EngineConfig".to_string(),
        });
        return;
    }
    let main_code: String =
        main.text.lines().map(code_portion).collect::<Vec<_>>().join("\n");
    for f in &fields {
        let line = field_decl_line(&config.text, f);
        if !contains_word(&main_code, f) {
            out.push(Violation {
                rule: "config-coverage",
                path: CONFIG_PATH.to_string(),
                line,
                msg: format!(
                    "EngineConfig field `{f}` is not reachable from main.rs (plumb a \
                     --flag through serve/bench, or name it where it is set)"
                ),
            });
        }
        if !tree.doc_pages().any(|d| contains_word(&d.text, f)) {
            out.push(Violation {
                rule: "config-coverage",
                path: CONFIG_PATH.to_string(),
                line,
                msg: format!("EngineConfig field `{f}` is never mentioned in rust/docs/"),
            });
        }
    }
}

fn missing_file(rule: &'static str, path: &str) -> Violation {
    Violation {
        rule,
        path: path.to_string(),
        line: 0,
        msg: "file not found in repo snapshot".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::SourceFile;

    fn metrics_fixture() -> String {
        "pub struct BatchIterRecord {\n    pub live_direct: usize,\n    pub live_via: usize,\n\
         }\n\npub struct BatchRunMetrics {\n    pub iters: usize,\n}\n\n\
         pub struct RunMetrics {\n    pub requests: usize,\n}\n\nimpl BatchRunMetrics {\n    \
         pub fn agg(&self) -> f64 {\n        self.live_via as f64 + self.iters as f64\n    }\n\
         }\n\nimpl RunMetrics {\n    pub fn count(&self) -> usize {\n        self.requests\n    \
         }\n}\n"
            .to_string()
    }

    fn tree(metrics: String, main: &str, docs: &str) -> RepoTree {
        RepoTree {
            files: vec![
                SourceFile { path: METRICS_PATH.into(), text: metrics },
                SourceFile { path: MAIN_PATH.into(), text: main.to_string() },
                SourceFile {
                    path: CONFIG_PATH.into(),
                    text: "pub struct EngineConfig {\n    pub seed: u64,\n    pub knob: \
                           usize,\n}\n"
                        .to_string(),
                },
                SourceFile { path: "rust/docs/serving.md".into(), text: docs.to_string() },
            ],
        }
    }

    fn run(t: &RepoTree) -> Vec<Violation> {
        let mut v = Vec::new();
        check(t, &mut v);
        v
    }

    #[test]
    fn live_fields_and_covered_config_pass() {
        let t = tree(
            metrics_fixture(),
            "fn serve() { let seed = 1; let knob = 2; print(m.live_direct, m.agg(), \
             m.count()); }",
            "`seed` and `knob` are documented here",
        );
        let v = run(&t);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn dead_field_is_flagged_with_struct_and_line() {
        // live_via is only reachable through agg(), and no emitter calls
        // agg() — both it and the never-read live_direct must flag.
        let t = tree(
            metrics_fixture(),
            "fn serve() { let seed = 1; let knob = 2; print(m.count()); }",
            "`seed` and `knob` are documented here",
        );
        let v = run(&t);
        let dead: Vec<&Violation> =
            v.iter().filter(|v| v.rule == "telemetry-dead-field").collect();
        assert_eq!(dead.len(), 3, "{v:?}"); // live_direct, live_via, iters
        assert!(dead.iter().any(|v| v.msg.contains("`live_direct`") && v.line == 2));
        assert!(dead.iter().any(|v| v.msg.contains("BatchRunMetrics field `iters`")));
    }

    #[test]
    fn method_indirection_keeps_a_field_live() {
        // live_via has no direct emitter mention, but agg() reads it and
        // an emitter calls agg().
        let t = tree(
            metrics_fixture(),
            "fn serve() { let seed = 1; let knob = 2; print(m.live_direct, m.agg(), \
             m.count()); }",
            "`seed` and `knob` are documented here",
        );
        assert!(run(&t).iter().all(|v| !v.msg.contains("`live_via`")));
    }

    #[test]
    fn unflagged_or_undocumented_config_field_fails() {
        let t = tree(
            metrics_fixture(),
            "fn serve() { let seed = 1; print(m.live_direct, m.agg(), m.count()); }",
            "only `seed` is documented here",
        );
        let v = run(&t);
        let cfg: Vec<&Violation> = v.iter().filter(|v| v.rule == "config-coverage").collect();
        assert_eq!(cfg.len(), 2, "{v:?}");
        assert!(cfg.iter().any(|v| v.msg.contains("main.rs")));
        assert!(cfg.iter().any(|v| v.msg.contains("rust/docs")));
        assert!(cfg.iter().all(|v| v.msg.contains("`knob`")));
    }

    #[test]
    fn emitter_mentions_in_comments_do_not_count() {
        let t = tree(
            metrics_fixture(),
            "fn serve() { let seed = 1; let knob = 2; print(m.agg(), m.count()); }\n\
             // live_direct is mentioned only in this comment\n",
            "`seed` and `knob` are documented here",
        );
        let v = run(&t);
        assert!(
            v.iter().any(|v| v.msg.contains("`live_direct`")),
            "comment mention must not keep the field live: {v:?}"
        );
    }
}
