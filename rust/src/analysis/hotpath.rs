//! Hot-path expert-set lint.
//!
//! The per-iteration serving loop was rebuilt around
//! [`crate::cost::bitmap::ExpertBitmap`] — fixed-size word arrays whose
//! union/intersection/difference/popcount are a handful of integer ops with
//! zero allocation (rust/docs/perf.md). A tree set on that path would
//! silently reintroduce the per-id allocation and pointer-chasing tax the
//! rebuild removed, and nothing in the type system stops it: the old code
//! compiled fine. This rule does — `BTreeSet` may not appear in code lines
//! of `rust/src/sim/`, `rust/src/coordinator/`, or `rust/src/cost/`.
//!
//! The one exemption is the bitmap module itself: its differential tests
//! deliberately hold the tree set as the *reference model* the bitmap is
//! pinned against. Anywhere else, a genuine off-hot-path need takes a
//! justified per-line allow (rust/docs/lints.md).

use super::{allowed, code_portion, contains_word, RepoTree, SourceFile, Violation};

/// Banned token, assembled from pieces so this file never flags itself.
const NEEDLE: &str = concat!("BTree", "Set");

/// The sanctioned dense-set module: its tests use the tree set as the
/// differential reference the bitmap is verified against.
const EXEMPT: &str = "rust/src/cost/bitmap.rs";

/// Directories whose per-iteration code must stay on `ExpertBitmap`.
const HOT_DIRS: &[&str] = &["rust/src/sim/", "rust/src/coordinator/", "rust/src/cost/"];

/// Is `path` subject to the rule?
pub fn in_scope(path: &str) -> bool {
    path != EXEMPT && HOT_DIRS.iter().any(|d| path.starts_with(d))
}

/// Sweep every in-scope crate source.
pub fn check(tree: &RepoTree, out: &mut Vec<Violation>) {
    for file in tree.rust_sources() {
        if in_scope(&file.path) {
            check_file(file, out);
        }
    }
}

/// Line sweep over one file (the fixture self-tests drive this directly).
pub fn check_file(file: &SourceFile, out: &mut Vec<Violation>) {
    let lines: Vec<&str> = file.text.lines().collect();
    for (i, raw) in lines.iter().enumerate() {
        let code = code_portion(raw);
        if contains_word(code, NEEDLE) && !allowed(&lines, i, "hot-path-set") {
            out.push(Violation {
                rule: "hot-path-set",
                path: file.path.clone(),
                line: i + 1,
                msg: format!(
                    "`{NEEDLE}` on the serving hot path: expert sets in sim/, \
                     coordinator/, and cost/ use cost::bitmap::ExpertBitmap \
                     (word-ops, no per-id allocation; rust/docs/perf.md)"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ALLOW_TOKEN;

    fn sweep(path: &str, text: String) -> Vec<Violation> {
        let file = SourceFile { path: path.into(), text };
        let mut out = Vec::new();
        if in_scope(&file.path) {
            check_file(&file, &mut out);
        }
        out
    }

    #[test]
    fn clean_hot_path_source_passes() {
        let v = sweep(
            "rust/src/sim/fixture.rs",
            "use crate::cost::ExpertBitmap;\nfn f() { let s = ExpertBitmap::new(); }\n"
                .to_string(),
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn tree_set_in_hot_dir_flagged_with_file_and_line() {
        let ty = concat!("BTree", "Set");
        let v = sweep(
            "rust/src/coordinator/fixture.rs",
            format!("fn f() {{\n    let s: std::collections::{ty}<usize> = Default::default();\n}}\n"),
        );
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "hot-path-set");
        assert_eq!((v[0].path.as_str(), v[0].line), ("rust/src/coordinator/fixture.rs", 2));
    }

    #[test]
    fn outside_hot_dirs_and_bitmap_module_are_exempt() {
        let ty = concat!("BTree", "Set");
        let text = format!("fn f() {{ let s: std::collections::{ty}<u32> = Default::default(); }}\n");
        assert!(sweep("rust/src/metrics/mod.rs", text.clone()).is_empty());
        assert!(sweep("rust/src/cost/bitmap.rs", text).is_empty());
    }

    #[test]
    fn justified_allow_suppresses() {
        let ty = concat!("BTree", "Set");
        let v = sweep(
            "rust/src/cost/fixture.rs",
            format!(
                "fn f() {{\n    // {ALLOW_TOKEN}(hot-path-set): cold-path audit \
                 aggregation, runs once per serve\n    let s: std::collections::{ty}<usize> \
                 = Default::default();\n}}\n"
            ),
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn tree_set_in_comment_is_ignored() {
        let ty = concat!("BTree", "Set");
        let v = sweep(
            "rust/src/sim/fixture.rs",
            format!("fn f() {{}} // the {ty} these bitmaps replaced\n"),
        );
        assert!(v.is_empty(), "{v:?}");
    }
}
