//! Guided greedy sampling (DESIGN.md §Substitutions).
//!
//! Untrained mini models cannot produce task-coherent text, so each request
//! carries a *reference continuation* from the task corpus. The sampler
//! biases the model's logits toward the reference token; with probability
//! `eps` (a per-task "difficulty" knob) the bias is dropped and the model's
//! own argmax wins, injecting the prediction noise that makes drafter
//! accuracy — and therefore ETR — task-dependent, exactly the axis the
//! paper studies. The model, KV cache, router, and rejection sampler all
//! operate on the real sampled stream.

use crate::rng::Rng;

/// Greedy argmax over a logits row.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

/// Guided greedy sample: argmax of `logits + strength·onehot(guide)` unless
/// this position deviates (probability `eps`), in which case the raw argmax
/// is taken. `guide = None` (reference exhausted) also falls back to raw.
pub fn sample_guided(
    logits: &[f32],
    guide: Option<u32>,
    strength: f32,
    eps: f64,
    rng: &mut Rng,
) -> u32 {
    match guide {
        Some(g) if !rng.chance(eps) => {
            let raw = argmax(logits);
            let g_idx = g as usize;
            if g_idx >= logits.len() {
                return raw;
            }
            // Equivalent to argmax after adding `strength` at `g`, without
            // materializing a biased copy (hot path).
            let raw_v = logits[raw as usize];
            if logits[g_idx] + strength >= raw_v {
                g
            } else {
                raw
            }
        }
        _ => argmax(logits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-5.0, -2.0, -9.0]), 1);
    }

    #[test]
    fn argmax_ties_pick_first() {
        assert_eq!(argmax(&[1.0, 1.0]), 0);
    }

    #[test]
    fn strong_guide_wins() {
        let logits = [10.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(1);
        let got = sample_guided(&logits, Some(2), 48.0, 0.0, &mut rng);
        assert_eq!(got, 2);
    }

    #[test]
    fn weak_guide_loses() {
        let logits = [10.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(1);
        let got = sample_guided(&logits, Some(2), 1.0, 0.0, &mut rng);
        assert_eq!(got, 0);
    }

    #[test]
    fn no_guide_is_raw_argmax() {
        let logits = [0.0, 3.0, 1.0];
        let mut rng = Rng::new(1);
        assert_eq!(sample_guided(&logits, None, 48.0, 0.0, &mut rng), 1);
    }

    #[test]
    fn eps_rate_controls_deviation() {
        let logits = [10.0f32, 0.0, 0.0];
        let mut rng = Rng::new(7);
        let n = 10_000;
        let deviations = (0..n)
            .filter(|_| sample_guided(&logits, Some(2), 48.0, 0.25, &mut rng) != 2)
            .count();
        let rate = deviations as f64 / n as f64;
        assert!((0.22..0.28).contains(&rate), "{rate}");
    }

    #[test]
    fn guide_out_of_range_falls_back() {
        let logits = [1.0, 0.0];
        let mut rng = Rng::new(1);
        assert_eq!(sample_guided(&logits, Some(300), 48.0, 0.0, &mut rng), 0);
    }

    #[test]
    fn biased_equivalence() {
        // The shortcut must equal materializing the biased logits.
        let mut rng = Rng::new(42);
        for _ in 0..500 {
            let logits: Vec<f32> = (0..16).map(|_| (rng.f64() * 10.0 - 5.0) as f32).collect();
            let g = rng.below(16) as u32;
            let strength = (rng.f64() * 8.0) as f32;
            let fast = sample_guided(&logits, Some(g), strength, 0.0, &mut Rng::new(1));
            let mut biased = logits.clone();
            biased[g as usize] += strength;
            // Tie behaviour: the fast path prefers the guide on exact ties,
            // matching argmax-first-index only when the guide index is
            // earlier; accept either when exactly tied.
            let slow = argmax(&biased);
            if fast != slow {
                let (f, s) = (biased[fast as usize], biased[slow as usize]);
                assert!((f - s).abs() < 1e-6, "fast={fast} slow={slow}");
            }
        }
    }
}
