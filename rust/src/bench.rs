//! In-tree micro-benchmark harness (criterion-style output; the vendor set
//! has no criterion). Used by `rust/benches/*.rs` via `harness = false`.
//!
//! Methodology: warm-up, then timed batches until both a minimum duration
//! and a minimum iteration count are reached; reports mean / p50 / p95 and
//! a robust min.

use std::time::{Duration, Instant};

/// One benchmark's measurements (ns per iteration).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub iters: u64,
}

impl Measurement {
    fn sorted(&self) -> Vec<f64> {
        let mut v = self.samples_ns.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        v
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn percentile_ns(&self, p: f64) -> f64 {
        let s = self.sorted();
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    pub fn min_ns(&self) -> f64 {
        self.sorted()[0]
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark runner. Collects results and prints a criterion-like report.
pub struct Bench {
    pub group: String,
    pub min_duration: Duration,
    pub min_samples: usize,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // CASCADE_BENCH_FAST=1 shrinks runs (CI smoke).
        let fast = std::env::var("CASCADE_BENCH_FAST").is_ok();
        Self {
            group: group.to_string(),
            min_duration: if fast { Duration::from_millis(50) } else { Duration::from_millis(400) },
            min_samples: if fast { 5 } else { 20 },
            results: Vec::new(),
        }
    }

    /// Time `f` (called once per iteration).
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Measurement {
        // Warm-up: one call, then estimate batch size.
        let t0 = Instant::now(); // lint:allow(wall-clock): the bench harness measures host wall time by design
        std::hint::black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(20));
        let batch = (Duration::from_millis(10).as_nanos() / once.as_nanos()).clamp(1, 10_000) as u64;

        let mut samples = Vec::new();
        let mut iters = 0u64;
        let start = Instant::now(); // lint:allow(wall-clock): the bench harness measures host wall time by design
        while start.elapsed() < self.min_duration || samples.len() < self.min_samples {
            let t = Instant::now(); // lint:allow(wall-clock): the bench harness measures host wall time by design
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
            iters += batch;
            if samples.len() > 5_000 {
                break;
            }
        }
        let m = Measurement { name: name.to_string(), samples_ns: samples, iters };
        println!(
            "{}/{:<40} mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}  ({} iters)",
            self.group,
            m.name,
            fmt_ns(m.mean_ns()),
            fmt_ns(m.percentile_ns(0.5)),
            fmt_ns(m.percentile_ns(0.95)),
            fmt_ns(m.min_ns()),
            m.iters
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Report a pre-measured quantity (e.g. end-to-end run stats).
    pub fn report(&self, name: &str, value: f64, unit: &str) {
        println!("{}/{:<40} {value:.3} {unit}", self.group, name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("CASCADE_BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let m = b.bench("noop-ish", || std::hint::black_box(3u64.wrapping_mul(7)));
        assert!(m.mean_ns() >= 0.0);
        assert!(m.iters > 0);
    }

    #[test]
    fn percentiles_ordered() {
        let m = Measurement {
            name: "x".into(),
            samples_ns: vec![5.0, 1.0, 9.0, 3.0, 7.0],
            iters: 5,
        };
        assert_eq!(m.min_ns(), 1.0);
        assert!(m.percentile_ns(0.5) <= m.percentile_ns(0.95));
    }
}
