//! Byte-level tokenizer.
//!
//! The model zoo's vocabulary is 320: raw bytes 0–255 plus special tokens.
//! Byte-level tokenization keeps the build free of trained BPE tables while
//! preserving the text statistics (n-gram repetition, span copying) that
//! drive drafter accuracy — the property the paper's task mix depends on.

/// Vocabulary size baked into the AOT models (configs.py).
pub const VOCAB: usize = 320;
pub const PAD: u32 = 256;
pub const BOS: u32 = 257;
pub const EOS: u32 = 258;

/// Encode text to token ids (one id per byte).
pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

/// Decode token ids back to text; specials render as markers.
pub fn decode(tokens: &[u32]) -> String {
    let mut out = String::with_capacity(tokens.len());
    for &t in tokens {
        match t {
            0..=255 => out.push(t as u8 as char),
            PAD => out.push_str("<pad>"),
            BOS => out.push_str("<bos>"),
            EOS => out.push_str("<eos>"),
            _ => out.push_str("<unk>"),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "def f(x):\n    return x + 1\n";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn all_ids_below_vocab() {
        let toks = encode("hello \u{00ff} world");
        assert!(toks.iter().all(|&t| (t as usize) < VOCAB));
    }

    #[test]
    fn specials_render() {
        assert_eq!(decode(&[BOS, b'a' as u32, EOS]), "<bos>a<eos>");
    }

    #[test]
    fn empty() {
        assert!(encode("").is_empty());
        assert_eq!(decode(&[]), "");
    }
}
