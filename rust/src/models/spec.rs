//! Paper-scale model specifications (Table 1) for the cost model.
//!
//! The mini models reproduce the *routing topology*; these specs carry the
//! *parameter scale* so that `cost::GpuCostModel` can convert measured
//! expert activations into GPU memory traffic for the hardware the paper
//! used (RTX 6000 Ada). Derivation: with `P_total = P_base + L·E·P_exp` and
//! `P_active = P_base + L·k·P_exp`, Table 1's (total, active) pairs pin
//! `P_exp = (P_total − P_active) / (L·(E−k))` and `P_base` (attention,
//! embeddings, router, and always-on shared experts).

use anyhow::{bail, Result};

pub const ALL_MODELS: &[&str] = &["mixtral", "phi", "olmoe", "deepseek", "qwen", "llama"];
pub const ALL_MOE_MODELS: &[&str] = &["mixtral", "phi", "olmoe", "deepseek", "qwen"];

/// Paper-scale spec of one zoo model.
#[derive(Debug, Clone)]
pub struct PaperScaleSpec {
    pub name: &'static str,
    /// Transformer layer count of the *paper-scale* model.
    pub layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_shared: usize,
    /// Bytes per parameter (FP8 = 1, FP16 = 2; Table 1 dtype column).
    pub dtype_bytes: f64,
    /// Routed-expert parameters, per expert per layer.
    pub expert_params: f64,
    /// Always-fetched active parameters per iteration (attention, embeddings,
    /// router, shared experts).
    pub base_params: f64,
    pub total_params: f64,
    pub active_params: f64,
}

impl PaperScaleSpec {
    /// Bytes of one routed expert (one layer).
    pub fn expert_bytes(&self) -> f64 {
        self.expert_params * self.dtype_bytes
    }

    /// Bytes always moved per iteration regardless of token count.
    pub fn base_bytes(&self) -> f64 {
        self.base_params * self.dtype_bytes
    }

    /// Bytes moved by a non-speculative decode step (= active params).
    pub fn active_bytes(&self) -> f64 {
        self.active_params * self.dtype_bytes
    }

    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }
}

fn moe(
    name: &'static str,
    layers: usize,
    n_experts: usize,
    top_k: usize,
    n_shared: usize,
    dtype_bytes: f64,
    total: f64,
    active: f64,
) -> PaperScaleSpec {
    let expert_params = (total - active) / (layers as f64 * (n_experts - top_k) as f64);
    let base_params = active - layers as f64 * top_k as f64 * expert_params;
    PaperScaleSpec {
        name,
        layers,
        n_experts,
        top_k,
        n_shared,
        dtype_bytes,
        expert_params,
        base_params,
        total_params: total,
        active_params: active,
    }
}

/// Table 1 rows. Layer counts: Mixtral/Phi 32, OLMoE 16, DeepSeekV1 28,
/// Qwen-1.5 24 (paper Table 1 "Hidden, Layers" column).
pub fn paper_spec(name: &str) -> Result<PaperScaleSpec> {
    Ok(match name {
        "mixtral" => moe("mixtral", 32, 8, 2, 0, 1.0, 47e9, 13e9),
        "phi" => moe("phi", 32, 16, 2, 0, 1.0, 42e9, 6.6e9),
        "olmoe" => moe("olmoe", 16, 64, 8, 0, 1.0, 7e9, 1e9),
        "deepseek" => moe("deepseek", 28, 64, 6, 2, 2.0, 16.4e9, 2.8e9),
        "qwen" => moe("qwen", 24, 60, 4, 4, 2.0, 14e9, 2.7e9),
        // Dense baseline: every iteration moves all 8B params at FP16.
        "llama" => PaperScaleSpec {
            name: "llama",
            layers: 32,
            n_experts: 0,
            top_k: 0,
            n_shared: 0,
            dtype_bytes: 2.0,
            expert_params: 0.0,
            base_params: 8e9,
            total_params: 8e9,
            active_params: 8e9,
        },
        // EAGLE-lite drafter: ~0.33B FP16 ⇒ drafting one token costs ≈5% of a
        // Mixtral baseline iteration (paper §7.3: "drafting overheads grow by
        // 5% per unit increase in K").
        "draft" => PaperScaleSpec {
            name: "draft",
            layers: 2,
            n_experts: 0,
            top_k: 0,
            n_shared: 0,
            dtype_bytes: 2.0,
            expert_params: 0.0,
            base_params: 0.33e9,
            total_params: 0.33e9,
            active_params: 0.33e9,
        },
        other => bail!("no paper-scale spec for model {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_totals_recovered() {
        for name in ALL_MOE_MODELS {
            let s = paper_spec(name).unwrap();
            let total = s.base_params
                + s.layers as f64 * s.n_experts as f64 * s.expert_params;
            let active =
                s.base_params + s.layers as f64 * s.top_k as f64 * s.expert_params;
            assert!((total - s.total_params).abs() / s.total_params < 1e-9, "{name}");
            assert!((active - s.active_params).abs() / s.active_params < 1e-9, "{name}");
        }
    }

    #[test]
    fn mixtral_expert_size_plausible() {
        // (47B - 13B) / (32 * 6) ≈ 177M params per expert per layer.
        let s = paper_spec("mixtral").unwrap();
        assert!((s.expert_params - 177.08e6).abs() < 1e6);
        assert!(s.base_params > 1e9 && s.base_params < 2e9);
    }

    #[test]
    fn base_params_positive() {
        for name in ALL_MODELS {
            let s = paper_spec(name).unwrap();
            assert!(s.base_params > 0.0, "{name}: {}", s.base_params);
        }
    }

    #[test]
    fn dense_has_no_experts() {
        let s = paper_spec("llama").unwrap();
        assert!(!s.is_moe());
        assert_eq!(s.active_bytes(), s.base_bytes());
    }

    #[test]
    fn fp16_models_double_bytes() {
        let q = paper_spec("qwen").unwrap();
        assert_eq!(q.dtype_bytes, 2.0);
        assert!((q.active_bytes() - 5.4e9).abs() < 1e8);
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(paper_spec("nope").is_err());
    }
}
