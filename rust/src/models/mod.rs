//! Model registry: binds AOT artifacts (`artifacts/manifest.json`) to
//! paper-scale specifications used by the cost model.

mod manifest;
mod spec;

pub use manifest::{GoldenOutputs, Manifest, ModelEntry, MiniConfig, VariantEntry, WeightsEntry};
pub use spec::{paper_spec, PaperScaleSpec, ALL_MOE_MODELS, ALL_MODELS};

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// A resolved model: mini config (what the HLO executes) + paper-scale spec
/// (what the cost model charges for).
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub mini: MiniConfig,
    pub paper: PaperScaleSpec,
    pub golden: GoldenOutputs,
    pub weights: WeightsEntry,
    /// Absolute path of weights.npz.
    pub weights_path: PathBuf,
    /// token-count -> absolute HLO path
    variants: Vec<(usize, PathBuf)>,
}

impl Model {
    /// Absolute path of the step variant for `t` in-flight tokens.
    pub fn variant_path(&self, t: usize) -> Result<&Path> {
        self.variants
            .iter()
            .find(|(vt, _)| *vt == t)
            .map(|(_, p)| p.as_path())
            .with_context(|| format!("model {} has no T={t} variant", self.name))
    }

    /// All available token-count variants, ascending.
    pub fn token_variants(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.variants.iter().map(|(t, _)| *t).collect();
        v.sort_unstable();
        v
    }

    pub fn prefill_chunk(&self) -> usize {
        self.mini.prefill_chunk
    }

    /// Largest decode/verify variant = max speculation length + 1.
    pub fn max_verify_tokens(&self) -> usize {
        self.token_variants()
            .into_iter()
            .filter(|&t| t <= 8)
            .max()
            .unwrap_or(1)
    }
}

/// Registry over the artifacts directory.
pub struct Registry {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Registry {
    /// Load `artifacts/manifest.json`. `dir` defaults to `$CASCADE_ARTIFACTS`
    /// or `./artifacts` (see [`default_artifacts_dir`]).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let value = crate::util::json::parse(&raw).with_context(|| format!("parsing {path:?}"))?;
        let manifest = Manifest::from_json(&value).with_context(|| format!("decoding {path:?}"))?;
        if manifest.version != manifest::MANIFEST_VERSION {
            bail!(
                "manifest version {} != expected {}; re-run `make artifacts`",
                manifest.version,
                manifest::MANIFEST_VERSION
            );
        }
        Ok(Self { dir, manifest })
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Resolve a model by zoo key.
    pub fn model(&self, name: &str) -> Result<Model> {
        let entry = self
            .manifest
            .models
            .get(name)
            .with_context(|| format!("unknown model {name:?}; have {:?}", self.model_names()))?;
        let mut variants: Vec<(usize, PathBuf)> = entry
            .variants
            .values()
            .map(|v| (v.tokens, self.dir.join(&v.path)))
            .collect();
        variants.sort_by_key(|(t, _)| *t);
        Ok(Model {
            name: name.to_string(),
            mini: entry.config.clone(),
            paper: paper_spec(name)?,
            golden: entry.golden.clone(),
            weights: entry.weights.clone(),
            weights_path: self.dir.join(&entry.weights.path),
            variants,
        })
    }
}

impl Registry {
    /// Load the artifacts manifest when present, else fall back to the
    /// in-code builtin registry. Sim-backend serving, experiments, and
    /// tests need only the mini topology, which the builtin carries; the
    /// real backend additionally needs the AOT HLO + weights on disk and
    /// reports a clear error without them.
    ///
    /// The fallback triggers only when `manifest.json` is *absent*: a
    /// manifest that exists but fails to load is a build problem that must
    /// surface, not be papered over with builtin topology that may diverge
    /// from the artifacts actually on disk. This convenience form panics
    /// on that case (test helpers); error-handling callers (the CLI) use
    /// [`Registry::try_load_or_builtin`].
    pub fn load_or_builtin(dir: impl AsRef<Path>) -> Self {
        Self::try_load_or_builtin(dir)
            .expect("artifacts manifest present but invalid; re-run `make artifacts`")
    }

    /// Non-panicking [`Registry::load_or_builtin`]: errors only when a
    /// manifest is present but fails to load.
    pub fn try_load_or_builtin(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if dir.join("manifest.json").exists() {
            Self::load(&dir)
        } else {
            Ok(Self::builtin_at(dir))
        }
    }

    /// In-code registry mirroring `python/compile/configs.py` exactly:
    /// same zoo, same routing topology, same affinity — no artifacts
    /// directory required.
    pub fn builtin() -> Self {
        Self::builtin_at(default_artifacts_dir())
    }

    fn builtin_at(dir: PathBuf) -> Self {
        Self { dir, manifest: builtin_manifest() }
    }
}

/// Are the AOT artifacts (HLO text + weights + goldens) on disk? Gates the
/// real-backend test suites; the sim backend never needs them.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

/// The model zoo of `python/compile/configs.py`, as manifest entries with
/// no on-disk artifacts (empty variant map, placeholder weight entries).
fn builtin_manifest() -> Manifest {
    #[allow(clippy::too_many_arguments)]
    fn entry(
        name: &str,
        mirrors: &str,
        hidden: usize,
        layers: usize,
        heads: usize,
        ffn: usize,
        n_experts: usize,
        top_k: usize,
        n_shared: usize,
        affinity: f64,
    ) -> ModelEntry {
        ModelEntry {
            config: MiniConfig {
                name: name.into(),
                mirrors: mirrors.into(),
                hidden,
                layers,
                heads,
                head_dim: 16,
                vocab: crate::tokenizer::VOCAB,
                ffn,
                n_experts,
                top_k,
                n_shared,
                affinity,
                max_seq: 384,
                prefill_chunk: 64,
                is_moe: n_experts > 0,
            },
            impl_name: "builtin".into(),
            weights: WeightsEntry {
                path: format!("weights/{name}.npz"),
                count: 0,
                names: Vec::new(),
                params: 0,
            },
            variants: std::collections::BTreeMap::new(),
            golden: GoldenOutputs {
                tokens: Vec::new(),
                t: 0,
                logits_row0_head: Vec::new(),
                logits_sum: 0.0,
                logits_abs_sum: 0.0,
                argmax: Vec::new(),
                topk_idx: Vec::new(),
                kv_abs_sum: 0.0,
                rstate_abs_sum: 0.0,
            },
        }
    }

    let mut models = std::collections::BTreeMap::new();
    models.insert(
        "mixtral".into(),
        entry("mixtral", "Mixtral-8x7B FP8", 64, 2, 4, 128, 8, 2, 0, 0.0),
    );
    models.insert(
        "phi".into(),
        entry("phi", "Phi-3.5-MoE FP8", 64, 2, 4, 128, 16, 2, 0, 0.20),
    );
    models.insert(
        "olmoe".into(),
        entry("olmoe", "OLMoE FP8", 64, 2, 4, 64, 64, 8, 0, 0.75),
    );
    models.insert(
        "deepseek".into(),
        entry("deepseek", "DeepSeekMoE-16B FP16", 64, 2, 4, 64, 64, 6, 2, 0.40),
    );
    models.insert(
        "qwen".into(),
        entry("qwen", "Qwen1.5-MoE FP16", 64, 2, 4, 64, 60, 4, 4, 0.45),
    );
    models.insert(
        "llama".into(),
        entry("llama", "LLaMA-3-8B dense FP16", 64, 2, 4, 256, 0, 0, 0, 0.0),
    );
    models.insert(
        "draft".into(),
        entry("draft", "EAGLE drafter (Mixtral)", 32, 1, 2, 64, 0, 0, 0, 0.0),
    );
    Manifest { version: manifest::MANIFEST_VERSION, impl_name: "builtin".into(), models }
}

/// `$CASCADE_ARTIFACTS` or `<crate root>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CASCADE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Registry {
        Registry::load_or_builtin(default_artifacts_dir())
    }

    #[test]
    fn loads_all_zoo_models() {
        let r = registry();
        for name in ALL_MODELS {
            let m = r.model(name).unwrap();
            assert_eq!(m.name, *name);
        }
    }

    #[test]
    fn builtin_registry_matches_configs_py() {
        let r = Registry::builtin();
        for name in ALL_MODELS {
            let m = r.model(name).unwrap();
            assert_eq!(m.mini.vocab, crate::tokenizer::VOCAB, "{name}");
            assert_eq!(m.mini.max_seq, 384, "{name}");
            assert_eq!(m.mini.is_moe, m.mini.n_experts > 0, "{name}");
        }
        assert!(r.model("draft").is_ok());
    }

    #[test]
    fn unknown_model_errors() {
        assert!(registry().model("gpt-17").is_err());
    }

    #[test]
    fn variant_paths_exist() {
        if !artifacts_available() {
            eprintln!("skipping variant_paths_exist: artifacts not built (run `make artifacts`)");
            return;
        }
        let m = registry().model("mixtral").unwrap();
        for t in m.token_variants() {
            assert!(m.variant_path(t).unwrap().exists());
        }
    }

    #[test]
    fn decode_variants_cover_k_sweep() {
        if !artifacts_available() {
            eprintln!(
                "skipping decode_variants_cover_k_sweep: artifacts not built (run `make artifacts`)"
            );
            return;
        }
        let m = registry().model("mixtral").unwrap();
        let ts = m.token_variants();
        for t in 1..=8 {
            assert!(ts.contains(&t), "missing T={t}");
        }
        assert_eq!(m.max_verify_tokens(), 8);
    }

    #[test]
    fn topology_matches_table1() {
        let r = registry();
        let mix = r.model("mixtral").unwrap();
        assert_eq!((mix.mini.n_experts, mix.mini.top_k, mix.mini.n_shared), (8, 2, 0));
        let ds = r.model("deepseek").unwrap();
        assert_eq!((ds.mini.n_experts, ds.mini.top_k, ds.mini.n_shared), (64, 6, 2));
        let olmoe = r.model("olmoe").unwrap();
        assert_eq!((olmoe.mini.n_experts, olmoe.mini.top_k), (64, 8));
    }
}
