//! Model registry: binds AOT artifacts (`artifacts/manifest.json`) to
//! paper-scale specifications used by the cost model.

mod manifest;
mod spec;

pub use manifest::{GoldenOutputs, Manifest, ModelEntry, MiniConfig, VariantEntry, WeightsEntry};
pub use spec::{paper_spec, PaperScaleSpec, ALL_MOE_MODELS, ALL_MODELS};

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// A resolved model: mini config (what the HLO executes) + paper-scale spec
/// (what the cost model charges for).
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub mini: MiniConfig,
    pub paper: PaperScaleSpec,
    pub golden: GoldenOutputs,
    pub weights: WeightsEntry,
    /// Absolute path of weights.npz.
    pub weights_path: PathBuf,
    /// token-count -> absolute HLO path
    variants: Vec<(usize, PathBuf)>,
}

impl Model {
    /// Absolute path of the step variant for `t` in-flight tokens.
    pub fn variant_path(&self, t: usize) -> Result<&Path> {
        self.variants
            .iter()
            .find(|(vt, _)| *vt == t)
            .map(|(_, p)| p.as_path())
            .with_context(|| format!("model {} has no T={t} variant", self.name))
    }

    /// All available token-count variants, ascending.
    pub fn token_variants(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.variants.iter().map(|(t, _)| *t).collect();
        v.sort_unstable();
        v
    }

    pub fn prefill_chunk(&self) -> usize {
        self.mini.prefill_chunk
    }

    /// Largest decode/verify variant = max speculation length + 1.
    pub fn max_verify_tokens(&self) -> usize {
        self.token_variants()
            .into_iter()
            .filter(|&t| t <= 8)
            .max()
            .unwrap_or(1)
    }
}

/// Registry over the artifacts directory.
pub struct Registry {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Registry {
    /// Load `artifacts/manifest.json`. `dir` defaults to `$CASCADE_ARTIFACTS`
    /// or `./artifacts` (see [`default_artifacts_dir`]).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let value = crate::util::json::parse(&raw).with_context(|| format!("parsing {path:?}"))?;
        let manifest = Manifest::from_json(&value).with_context(|| format!("decoding {path:?}"))?;
        if manifest.version != manifest::MANIFEST_VERSION {
            bail!(
                "manifest version {} != expected {}; re-run `make artifacts`",
                manifest.version,
                manifest::MANIFEST_VERSION
            );
        }
        Ok(Self { dir, manifest })
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Resolve a model by zoo key.
    pub fn model(&self, name: &str) -> Result<Model> {
        let entry = self
            .manifest
            .models
            .get(name)
            .with_context(|| format!("unknown model {name:?}; have {:?}", self.model_names()))?;
        let mut variants: Vec<(usize, PathBuf)> = entry
            .variants
            .values()
            .map(|v| (v.tokens, self.dir.join(&v.path)))
            .collect();
        variants.sort_by_key(|(t, _)| *t);
        Ok(Model {
            name: name.to_string(),
            mini: entry.config.clone(),
            paper: paper_spec(name)?,
            golden: entry.golden.clone(),
            weights: entry.weights.clone(),
            weights_path: self.dir.join(&entry.weights.path),
            variants,
        })
    }
}

/// `$CASCADE_ARTIFACTS` or `<crate root>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CASCADE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Registry {
        Registry::load(default_artifacts_dir()).expect("run `make artifacts`")
    }

    #[test]
    fn loads_all_zoo_models() {
        let r = registry();
        for name in ALL_MODELS {
            let m = r.model(name).unwrap();
            assert_eq!(m.name, *name);
        }
    }

    #[test]
    fn unknown_model_errors() {
        assert!(registry().model("gpt-17").is_err());
    }

    #[test]
    fn variant_paths_exist() {
        let m = registry().model("mixtral").unwrap();
        for t in m.token_variants() {
            assert!(m.variant_path(t).unwrap().exists());
        }
    }

    #[test]
    fn decode_variants_cover_k_sweep() {
        let m = registry().model("mixtral").unwrap();
        let ts = m.token_variants();
        for t in 1..=8 {
            assert!(ts.contains(&t), "missing T={t}");
        }
        assert_eq!(m.max_verify_tokens(), 8);
    }

    #[test]
    fn topology_matches_table1() {
        let r = registry();
        let mix = r.model("mixtral").unwrap();
        assert_eq!((mix.mini.n_experts, mix.mini.top_k, mix.mini.n_shared), (8, 2, 0));
        let ds = r.model("deepseek").unwrap();
        assert_eq!((ds.mini.n_experts, ds.mini.top_k, ds.mini.n_shared), (64, 6, 2));
        let olmoe = r.model("olmoe").unwrap();
        assert_eq!((olmoe.mini.n_experts, olmoe.mini.top_k), (64, 8));
    }
}
