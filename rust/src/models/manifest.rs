//! Mirror of `artifacts/manifest.json` (written by python/compile/aot.py),
//! parsed with the in-tree JSON substrate.

use crate::util::json::Value;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Must match `MANIFEST_VERSION` in aot.py; bumped on I/O contract changes.
pub const MANIFEST_VERSION: u64 = 3;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    /// Kernel implementation lowered into the HLO ("pallas" or "ref").
    pub impl_name: String,
    pub models: BTreeMap<String, ModelEntry>,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: MiniConfig,
    pub impl_name: String,
    pub weights: WeightsEntry,
    pub variants: BTreeMap<String, VariantEntry>,
    pub golden: GoldenOutputs,
}

/// Where the model's parameters live (fed to the step HLO as leading
/// arguments; see python/compile/weights.py for why they are not constants).
#[derive(Debug, Clone)]
pub struct WeightsEntry {
    pub path: String,
    pub count: usize,
    pub names: Vec<String>,
    pub params: u64,
}

/// The mini model's architecture — what the HLO actually computes.
#[derive(Debug, Clone)]
pub struct MiniConfig {
    pub name: String,
    pub mirrors: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub ffn: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_shared: usize,
    pub affinity: f64,
    pub max_seq: usize,
    pub prefill_chunk: usize,
    pub is_moe: bool,
}

impl MiniConfig {
    pub fn kv_dim(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Elements in the functional KV-cache tensor [L, 2, S, KVD].
    pub fn kv_elems(&self) -> usize {
        self.layers * 2 * self.max_seq * self.kv_dim()
    }

    /// Elements in the router-state tensor [L, H].
    pub fn rstate_elems(&self) -> usize {
        self.layers * self.hidden
    }

    /// Router top-k arity in the step output (dense models emit 1 sentinel).
    pub fn topk_arity(&self) -> usize {
        self.top_k.max(1)
    }
}

#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub path: String,
    pub tokens: usize,
    pub sha256: String,
    pub hlo_bytes: u64,
}

/// Eager-JAX outputs for a fixed input, proving the Rust PJRT path
/// reproduces L2 numerics (rust/tests/runtime_golden.rs).
#[derive(Debug, Clone)]
pub struct GoldenOutputs {
    pub tokens: Vec<u32>,
    pub t: usize,
    pub logits_row0_head: Vec<f32>,
    pub logits_sum: f64,
    pub logits_abs_sum: f64,
    pub argmax: Vec<usize>,
    /// [L][T][Kr] router picks.
    pub topk_idx: Vec<Vec<Vec<i32>>>,
    pub kv_abs_sum: f64,
    pub rstate_abs_sum: f64,
}

impl Manifest {
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut models = BTreeMap::new();
        for (name, entry) in v.req("models")?.as_obj()? {
            models.insert(
                name.clone(),
                ModelEntry::from_json(entry).with_context(|| format!("model {name}"))?,
            );
        }
        Ok(Self {
            version: v.req("version")?.as_usize()? as u64,
            impl_name: v.req("impl")?.as_str()?.to_string(),
            models,
        })
    }
}

impl ModelEntry {
    fn from_json(v: &Value) -> Result<Self> {
        let mut variants = BTreeMap::new();
        for (t, var) in v.req("variants")?.as_obj()? {
            variants.insert(t.clone(), VariantEntry::from_json(var)?);
        }
        Ok(Self {
            config: MiniConfig::from_json(v.req("config")?)?,
            impl_name: v.req("impl")?.as_str()?.to_string(),
            weights: WeightsEntry::from_json(v.req("weights")?)?,
            variants,
            golden: GoldenOutputs::from_json(v.req("golden")?)?,
        })
    }
}

impl WeightsEntry {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            path: v.req("path")?.as_str()?.to_string(),
            count: v.req("count")?.as_usize()?,
            names: v
                .req("names")?
                .as_arr()?
                .iter()
                .map(|x| x.as_str().map(str::to_string))
                .collect::<Result<_>>()?,
            params: v.req("params")?.as_usize()? as u64,
        })
    }
}

impl MiniConfig {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            mirrors: v.req("mirrors")?.as_str()?.to_string(),
            hidden: v.req("hidden")?.as_usize()?,
            layers: v.req("layers")?.as_usize()?,
            heads: v.req("heads")?.as_usize()?,
            head_dim: v.req("head_dim")?.as_usize()?,
            vocab: v.req("vocab")?.as_usize()?,
            ffn: v.req("ffn")?.as_usize()?,
            n_experts: v.req("n_experts")?.as_usize()?,
            top_k: v.req("top_k")?.as_usize()?,
            n_shared: v.req("n_shared")?.as_usize()?,
            affinity: v.req("affinity")?.as_f64()?,
            max_seq: v.req("max_seq")?.as_usize()?,
            prefill_chunk: v.req("prefill_chunk")?.as_usize()?,
            is_moe: v.req("is_moe")?.as_bool()?,
        })
    }
}

impl VariantEntry {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            path: v.req("path")?.as_str()?.to_string(),
            tokens: v.req("tokens")?.as_usize()?,
            sha256: v.req("sha256")?.as_str()?.to_string(),
            hlo_bytes: v.req("hlo_bytes")?.as_usize()? as u64,
        })
    }
}

impl GoldenOutputs {
    fn from_json(v: &Value) -> Result<Self> {
        let usize_arr = |k: &str| -> Result<Vec<usize>> {
            v.req(k)?.as_arr()?.iter().map(|x| x.as_usize()).collect()
        };
        let f32_arr = |k: &str| -> Result<Vec<f32>> {
            v.req(k)?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64().map(|f| f as f32))
                .collect()
        };
        let topk_idx = v
            .req("topk_idx")?
            .as_arr()?
            .iter()
            .map(|l| {
                l.as_arr()?
                    .iter()
                    .map(|t| {
                        t.as_arr()?
                            .iter()
                            .map(|e| e.as_f64().map(|f| f as i32))
                            .collect::<Result<Vec<i32>>>()
                    })
                    .collect::<Result<Vec<Vec<i32>>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            tokens: usize_arr("tokens")?.into_iter().map(|t| t as u32).collect(),
            t: v.req("t")?.as_usize()?,
            logits_row0_head: f32_arr("logits_row0_head")?,
            logits_sum: v.req("logits_sum")?.as_f64()?,
            logits_abs_sum: v.req("logits_abs_sum")?.as_f64()?,
            argmax: usize_arr("argmax")?,
            topk_idx,
            kv_abs_sum: v.req("kv_abs_sum")?.as_f64()?,
            rstate_abs_sum: v.req("rstate_abs_sum")?.as_f64()?,
        })
    }
}
