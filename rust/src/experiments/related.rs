//! §8 analysis: why the *other* speculation families are infeasible for
//! MoEs (paper §8.1's Lookahead-Decoding and Medusa discussion), derived
//! from the cost model rather than claimed.
//!
//! For a technique that puts `n` tokens in flight per iteration, the
//! expected unique experts per layer under near-uniform routing is the
//! balls-in-bins bound the paper uses in §2.4:
//!
//!   E[unique] = E · (1 − (1 − k/E)^n)
//!
//! The verification cost ratio follows from Table 1 bytes, and the ETR a
//! technique must achieve just to break even is that ratio — giving the
//! paper's "4x–8x cost, ETR rarely justifies it" conclusion for Medusa
//! quantitatively.

use crate::cost::GpuCostModel;
use crate::experiments::runner::ExpCtx;
use crate::util::table::Table;
use anyhow::Result;

/// Expected unique experts per layer for `n` in-flight tokens.
pub fn expected_unique(n_experts: usize, top_k: usize, n_tokens: usize) -> f64 {
    let e = n_experts as f64;
    let k = top_k as f64;
    e * (1.0 - (1.0 - k / e).powi(n_tokens as i32))
}

/// The speculation families the paper's related work analyzes, with their
/// in-flight token counts at typical settings.
const TECHNIQUES: &[(&str, usize)] = &[
    ("no speculation", 1),
    ("n-gram / draft-model K=3", 4),
    ("n-gram / draft-model K=7", 8),
    ("Lookahead G=4, K=4", 17),  // G parallel n-grams + 1 (paper 8.1)
    ("Medusa 4 heads, tree=64", 64), // 50-100x in-flight tokens (paper 8.1)
];

pub fn related(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "8.1 analysis: in-flight tokens -> verification cost (balls-in-bins + Table 1)",
        &["model", "technique", "tokens", "E[unique]/layer", "verify cost", "break-even ETR"],
    );
    for name in ["mixtral", "olmoe"] {
        let model = ctx.registry.model(name)?;
        let cost = GpuCostModel::new(model.paper.clone(), model.mini.layers);
        let base = cost.baseline_cost().verify_s();
        for (tech, n) in TECHNIQUES {
            let uniq = expected_unique(model.paper.n_experts, model.paper.top_k, *n);
            let uniq_vec = vec![uniq.round() as usize; model.mini.layers];
            let c = cost
                .verify_cost(&uniq_vec, *n, n.saturating_sub(1), crate::config::DrafterKind::Ngram)
                .verify_s();
            t.row(vec![
                name.into(),
                tech.to_string(),
                n.to_string(),
                format!("{uniq:.1}/{}", model.paper.n_experts),
                format!("{:.2}x", c / base),
                format!("{:.2}", c / base),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_monotone_in_tokens() {
        let a = expected_unique(8, 2, 1);
        let b = expected_unique(8, 2, 4);
        let c = expected_unique(8, 2, 64);
        assert!(a < b && b < c);
        assert!((a - 2.0).abs() < 1e-9); // one token activates exactly top_k
        assert!(c <= 8.0 + 1e-9);
    }

    #[test]
    fn paper_balls_in_bins_example() {
        // Paper §2.4: Mixtral at K=7 (8 tokens, top-2 of 8) activates over
        // seven unique experts on average — a ~3.5x increase.
        let u = expected_unique(8, 2, 8);
        assert!(u > 7.0, "{u}");
        assert!((u / 2.0) > 3.4);
    }

    #[test]
    fn medusa_saturates_experts() {
        // Paper §8.1: Medusa's tree "would activate all experts every
        // iteration".
        let u = expected_unique(8, 2, 64);
        assert!(u > 7.99);
        let u64e = expected_unique(64, 8, 64);
        assert!(u64e > 63.0);
    }
}
