//! Prefix-sharing experiment (extension beyond the paper's evaluation):
//! TTFT and throughput vs the template share ratio, under copy-on-write KV
//! prefix reuse (rust/docs/prefix_cache.md).
//!
//! Template-heavy serving — every request opens with a fixed-length
//! preamble, drawn from a small shared template pool with probability
//! `share` and request-unique otherwise (`workload::with_prefix_templates`)
//! — is the regime the prefix trie is built for: a trie hit maps the
//! resident preamble blocks into the new request and charges only the
//! novel suffix's prefill on the virtual clock. The cells run **open-loop**
//! (Poisson arrivals fast enough to keep a queue standing): under backlog
//! a saved prefill chunk shortens not just the hitting request's TTFT but
//! every queued request behind it, so the p50 TTFT falls monotonically as
//! `share` rises. Every share level streams the *identical* prompt-length
//! and corpus distribution — only the preamble's cacheability changes — so
//! the TTFT deltas are attributable to cache hits alone. Shared by
//! `figure prefix` and the `bench` BENCH_prefix.json emitter so the two
//! can never drift.

use crate::coordinator::scheduler::{Budget, Scheduler};
use crate::experiments::runner::ExpCtx;
use crate::metrics::BatchRunMetrics;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::arrivals::{ArrivalKind, ArrivalProcess};
use crate::workload::{RequestStream, Workload};
use anyhow::Result;

/// Template share ratios on the experiment axis (0 = sharing off: the
/// engine runs without a trie and every preamble is request-unique).
pub const SHARES: [f64; 4] = [0.0, 0.3, 0.6, 0.9];

/// Batch sizes on the experiment axis.
pub const BATCHES: [usize; 2] = [1, 4];

/// Requests per cell the budget is sized for: enough template draws that
/// each share level separates (at 4 templates, share 0.3 re-draws a seen
/// template a handful of times; share 0.9 almost always).
const CELL_REQUESTS: usize = 24;

/// One prefix-sharing serving cell.
pub struct PrefixCell {
    /// Probability a request's preamble comes from the shared template
    /// pool; also the engine's `prefix_share` (0 disables the trie).
    pub share: f64,
    pub batch: usize,
    /// Poisson arrival rate (req/s on the virtual clock): deliberately
    /// above the service rate of both batch sizes, so a queue stands and
    /// prefill savings compound across waiting requests.
    pub rate: f64,
    /// Per-request output cap (short decodes keep the cell
    /// prefill-dominated — the axis under test).
    pub max_new: usize,
    /// Output-token budget of the cell.
    pub tokens: usize,
}

/// The canonical contended cell for a (share, batch) point.
pub fn cell(share: f64, batch: usize) -> PrefixCell {
    let max_new = 48usize;
    PrefixCell { share, batch, rate: 16.0, max_new, tokens: CELL_REQUESTS * max_new }
}

fn cell_workload() -> Workload {
    // code+math: both tasks leave headroom for the 128-token preamble
    // within the model's max_seq (extract's long passages do not).
    Workload::by_name("code+math").expect("known mix")
}

/// Serve one open-loop prefix cell on the sim backend.
pub fn run_cell(
    ctx: &ExpCtx,
    model: &str,
    policy: &PolicyKind,
    cell: &PrefixCell,
) -> Result<BatchRunMetrics> {
    let mut cfg = ctx.batch_cfg(model, cell.batch);
    cfg.max_new_tokens = cell.max_new;
    cfg.prefix_share = cell.share;
    let mut engine = ctx.batch_engine(cfg, policy)?;
    let stream = RequestStream::with_prefix_templates(
        cell_workload(),
        ctx.seed,
        cell.max_new,
        cell.share,
    );
    let arrivals =
        ArrivalProcess::new(ArrivalKind::Poisson { rate: cell.rate }, stream, ctx.seed)?;
    let mut sched = Scheduler::with_arrivals(
        arrivals,
        Budget { max_tokens: cell.tokens, max_requests: 10_000 },
    );
    sched.run_batched(&mut engine)
}

/// `figure prefix`: p50/p95 TTFT, throughput, and hit telemetry vs the
/// template share ratio at batch 1 and 4 (sim backend, open-loop).
pub fn prefix(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let probe = cell(0.0, 1);
    let mut t = Table::new(
        format!(
            "Prefix sharing (sim backend, code+math mix, poisson {:.0}/s open-loop): \
             TTFT vs template share ratio under copy-on-write KV reuse",
            probe.rate
        ),
        &[
            "batch",
            "share",
            "reqs",
            "tokens",
            "tok/s",
            "TTFT p50",
            "TTFT p95",
            "prefix_hits",
            "prefix_misses",
            "hit rate",
            "prefix_hit_tokens",
            "shared_blocks_peak",
            "prefix_reclaimed_blocks",
        ],
    );
    let policy = PolicyKind::Static(3);
    for &batch in &BATCHES {
        for &share in &SHARES {
            let c = cell(share, batch);
            let m = run_cell(ctx, "mixtral", &policy, &c)?;
            t.row(vec![
                batch.to_string(),
                format!("{share:.1}"),
                m.run.requests.len().to_string(),
                m.run.total_tokens().to_string(),
                format!("{:.1}", m.run.total_tokens() as f64 / m.clock_s),
                ms(m.run.ttft_percentile(0.50)),
                ms(m.run.ttft_percentile(0.95)),
                m.prefix_hits.to_string(),
                m.prefix_misses.to_string(),
                format!("{:.0}%", 100.0 * m.prefix_hit_rate()),
                m.prefix_hit_tokens.to_string(),
                m.shared_blocks_peak.to_string(),
                m.prefix_reclaimed_blocks.to_string(),
            ]);
        }
    }
    Ok(vec![t])
}
