//! One harness per paper table/figure. Each returns text tables whose rows
//! mirror what the paper plots; EXPERIMENTS.md records paper-vs-measured.

use crate::config::{CascadeParams, DrafterKind};
use crate::experiments::runner::{ExpCtx, RunSpec};
use crate::models::{ALL_MOE_MODELS, ALL_MODELS};
use crate::spec::policy::PolicyKind;
use crate::util::table::{ratio, Table};
use crate::workload::{Task, Workload};
use anyhow::Result;

fn f2(x: f64) -> String {
    format!("{x:.2}")
}

fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Table 1: the model zoo at paper scale + mini topology + calibrated
/// baseline iteration time.
pub fn table1(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 1: MoE models (paper scale -> cost model; mini topology -> HLO)",
        &["model", "mirrors", "experts", "top-k", "shared", "total", "active", "bytes/p", "base iter"],
    );
    for name in ALL_MODELS {
        let m = ctx.registry.model(name)?;
        let cost = crate::cost::GpuCostModel::new(m.paper.clone(), m.mini.layers);
        t.row(vec![
            name.to_string(),
            m.mini.mirrors.clone(),
            m.paper.n_experts.to_string(),
            m.paper.top_k.to_string(),
            m.paper.n_shared.to_string(),
            format!("{:.1}B", m.paper.total_params / 1e9),
            format!("{:.1}B", m.paper.active_params / 1e9),
            format!("{}", m.paper.dtype_bytes),
            format!("{:.1}ms", cost.baseline_cost().total() * 1e3),
        ]);
    }
    Ok(vec![t])
}

/// Fig. 1(c): static-K n-gram speculation on Mixtral across the 7 tasks.
/// Paper shape: every task has a losing K; math/extract lose at all K;
/// worst case ≈ 1.5x slowdown.
pub fn fig1c(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 1c: Mixtral TPOT speedup vs no-spec (n-gram, static K)",
        &["task", "K=1", "K=2", "K=3"],
    );
    for w in Workload::all_seven() {
        let mut row = vec![w.name.clone()];
        for k in 1..=3 {
            let s = ctx.speedup(&RunSpec::new("mixtral", w.clone(), PolicyKind::Static(k)))?;
            row.push(ratio(s));
        }
        t.row(row);
    }
    Ok(vec![t])
}

/// Fig. 4: dense (LLaMA) vs MoE (Mixtral), K = 1..7 — TPOT/ETR speedups
/// (top) and iteration-time breakdown (bottom).
pub fn fig4(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let tasks = [Task::Code, Task::Math, Task::Extract];
    let mut top = Table::new(
        "Fig 4 top: TPOT and ETR speedup vs K (dense llama vs MoE mixtral)",
        &["model", "task", "K", "TPOT speedup", "ETR"],
    );
    let mut bottom = Table::new(
        "Fig 4 bottom: iteration time breakdown (fractions of spec iteration)",
        &["model", "task", "K", "verify/base", "draft%", "reject%", "iter ms"],
    );
    for model in ["llama", "mixtral"] {
        for task in tasks {
            let w = Workload::single(task);
            let base = ctx.run(&RunSpec::new(model, w.clone(), PolicyKind::Static(0)))?;
            let base_iter = base.0.mean_iter_s;
            for k in 1..=7 {
                let (s, run) = ctx.run(&RunSpec::new(model, w.clone(), PolicyKind::Static(k)))?;
                top.row(vec![
                    model.into(),
                    w.name.clone(),
                    k.to_string(),
                    ratio(base.0.tpot_s / s.tpot_s),
                    f2(s.etr),
                ]);
                // Breakdown averaged over iterations.
                let iters: Vec<&crate::metrics::IterRecord> =
                    run.requests.iter().flat_map(|r| &r.iters).collect();
                let n = iters.len().max(1) as f64;
                let mean = |f: fn(&crate::cost::IterCost) -> f64| {
                    iters.iter().map(|r| f(&r.cost)).sum::<f64>() / n
                };
                let verify = mean(|c| c.base_s + c.expert_s + c.overhead_s);
                let draft = mean(|c| c.draft_s);
                let reject = mean(|c| c.reject_s);
                let total = mean(|c| c.total());
                bottom.row(vec![
                    model.into(),
                    w.name.clone(),
                    k.to_string(),
                    ratio(verify / base_iter),
                    format!("{:.1}%", 100.0 * draft / total),
                    format!("{:.1}%", 100.0 * reject / total),
                    format!("{:.1}", total * 1e3),
                ]);
            }
        }
    }
    Ok(vec![top, bottom])
}

/// Fig. 5: TPOT improvement across all 5 MoEs, 7 tasks, K in {1,2,3}.
pub fn fig5(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 5: TPOT speedup, 5 MoEs x 7 tasks x static K",
        &["model", "task", "K=1", "K=2", "K=3"],
    );
    for model in ALL_MOE_MODELS {
        for w in Workload::all_seven() {
            let mut row = vec![model.to_string(), w.name.clone()];
            for k in 1..=3 {
                let s = ctx.speedup(&RunSpec::new(model, w.clone(), PolicyKind::Static(k)))?;
                row.push(ratio(s));
            }
            t.row(row);
        }
    }
    Ok(vec![t])
}

/// Fig. 6: iteration-level ETR and cost variation for Phi + extraction at
/// static K=3 (5 requests, 16-iteration windows).
pub fn fig6(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let spec = RunSpec::new("phi", Workload::single(Task::Extract), PolicyKind::Static(3));
    let base = ctx.run(&RunSpec { policy: PolicyKind::Static(0), ..spec.clone() })?;
    let (_, run) = ctx.run(&spec)?;
    let mut t = Table::new(
        "Fig 6: windowed ETR and relative cost (phi + extract, K=3)",
        &["request", "window", "ETR", "cost", "utility"],
    );
    for (ri, req) in run.requests.iter().take(5).enumerate() {
        for w in req.utility_windows(16, base.0.mean_iter_s) {
            t.row(vec![
                format!("r{ri}"),
                w.window.to_string(),
                f2(w.etr),
                f2(w.cost),
                f2(w.utility),
            ]);
        }
    }
    Ok(vec![t])
}

/// Fig. 7: utility variation across requests for selected model/task/K
/// combinations (16-iteration windows + harmonic-mean line).
pub fn fig7(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let combos: [(&str, Task, usize); 4] = [
        ("phi", Task::Extract, 3),
        ("mixtral", Task::Math, 3),
        ("olmoe", Task::Extract, 3),
        ("qwen", Task::Code, 2),
    ];
    let mut tables = Vec::new();
    for (model, task, k) in combos {
        let spec = RunSpec::new(model, Workload::single(task), PolicyKind::Static(k));
        let base = ctx.run(&RunSpec { policy: PolicyKind::Static(0), ..spec.clone() })?;
        let (_, run) = ctx.run(&spec)?;
        let mut t = Table::new(
            format!("Fig 7: utility windows, {model} + {} @ K={k}", task.name()),
            &["request", "window", "utility"],
        );
        for (ri, req) in run.requests.iter().take(5).enumerate() {
            for w in req.utility_windows(16, base.0.mean_iter_s) {
                t.row(vec![format!("r{ri}"), w.window.to_string(), f2(w.utility)]);
            }
        }
        t.row(vec![
            "harmonic-mean".into(),
            "-".into(),
            f2(run.harmonic_mean_utility(base.0.mean_iter_s)),
        ]);
        tables.push(t);
    }
    Ok(tables)
}

/// Fig. 8: speedup as a function of measured utility over 5 models x 3
/// tasks x K in 0..7 — utility must predict speedup (paper: R^2 = 99.4%).
pub fn fig8(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let tasks = [Task::Code, Task::Math, Task::Extract];
    let mut t = Table::new(
        "Fig 8: measured utility vs TPOT speedup (Theorem 4.2)",
        &["model", "task", "K", "utility", "speedup"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for model in ALL_MOE_MODELS {
        for task in tasks {
            let w = Workload::single(task);
            let base = ctx.run(&RunSpec::new(model, w.clone(), PolicyKind::Static(0)))?;
            for k in 0..=7usize {
                let (s, _) = ctx.run(&RunSpec::new(model, w.clone(), PolicyKind::Static(k)))?;
                // Utility from mean ETR and mean iteration time (Def. 4.1).
                let utility = s.etr / (s.mean_iter_s / base.0.mean_iter_s);
                let speedup = base.0.tpot_s / s.tpot_s;
                xs.push(utility);
                ys.push(speedup);
                t.row(vec![
                    model.to_string(),
                    w.name.clone(),
                    k.to_string(),
                    f3(utility),
                    f3(speedup),
                ]);
            }
        }
    }
    let r2 = r_squared(&xs, &ys);
    let mut s = Table::new("Fig 8 summary", &["points", "R^2 (speedup ~ utility)"]);
    s.row(vec![xs.len().to_string(), format!("{:.4}", r2)]);
    Ok(vec![t, s])
}

/// Fig. 13 (headline): Cascade vs static-K on 5 MoEs x 7 tasks.
/// Paper shape: static worst cases -26%/-38%/-54% for K=1/2/3; Cascade
/// worst case -5%; Cascade beats best-static by 7-15% on average (except
/// OLMoE ~ -3%).
pub fn fig13(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let policies: Vec<(String, PolicyKind)> = vec![
        ("K=1".into(), PolicyKind::Static(1)),
        ("K=2".into(), PolicyKind::Static(2)),
        ("K=3".into(), PolicyKind::Static(3)),
        ("cascade".into(), PolicyKind::Cascade(CascadeParams::default())),
    ];
    let mut t = Table::new(
        "Fig 13: TPOT speedup vs no-spec (n-gram)",
        &["model", "task", "K=1", "K=2", "K=3", "cascade"],
    );
    let mut summary = Table::new(
        "Fig 13 summary",
        &["policy", "worst-case", "geomean", "wins-vs-best-static"],
    );
    let mut per_policy: Vec<Vec<f64>> = vec![Vec::new(); policies.len()];
    let mut cascade_vs_best = 0usize;
    let mut cells = 0usize;
    for model in ALL_MOE_MODELS {
        for w in Workload::all_seven() {
            let mut row = vec![model.to_string(), w.name.clone()];
            let mut vals = Vec::new();
            for (pi, (_, p)) in policies.iter().enumerate() {
                let s = ctx.speedup(&RunSpec::new(model, w.clone(), p.clone()))?;
                per_policy[pi].push(s);
                vals.push(s);
                row.push(ratio(s));
            }
            let best_static = vals[..3].iter().cloned().fold(f64::MIN, f64::max);
            if vals[3] >= best_static * 0.995 {
                cascade_vs_best += 1;
            }
            cells += 1;
            t.row(row);
        }
    }
    for (pi, (name, _)) in policies.iter().enumerate() {
        let v = &per_policy[pi];
        let worst = v.iter().cloned().fold(f64::MAX, f64::min);
        let geo = (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp();
        summary.row(vec![
            name.clone(),
            ratio(worst),
            ratio(geo),
            if pi == 3 { format!("{cascade_vs_best}/{cells}") } else { "-".into() },
        ]);
    }
    Ok(vec![t, summary])
}

/// Fig. 15: iteration-level utility for Mixtral+math under static K=3 vs
/// Cascade — Cascade must bound the slowdown near 5%.
pub fn fig15(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let w = Workload::single(Task::Math);
    let base = ctx.run(&RunSpec::new("mixtral", w.clone(), PolicyKind::Static(0)))?;
    let mut tables = Vec::new();
    for (label, policy) in [
        ("static-k3", PolicyKind::Static(3)),
        ("cascade", PolicyKind::Cascade(CascadeParams::default())),
    ] {
        let (s, run) = ctx.run(&RunSpec::new("mixtral", w.clone(), policy))?;
        let mut t = Table::new(
            format!("Fig 15: utility windows, mixtral + math, {label}"),
            &["request", "window", "utility"],
        );
        for (ri, req) in run.requests.iter().take(4).enumerate() {
            for win in req.utility_windows(16, base.0.mean_iter_s) {
                t.row(vec![format!("r{ri}"), win.window.to_string(), f2(win.utility)]);
            }
        }
        t.row(vec!["overall-speedup".into(), "-".into(), ratio(base.0.tpot_s / s.tpot_s)]);
        tables.push(t);
    }
    Ok(tables)
}

/// Fig. 16: utility trace for the all-3 mix on Mixtral under Cascade over a
/// long stream — Cascade adapts per request.
pub fn fig16(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let w = Workload::by_name("all-3").unwrap();
    let base = ctx.run(&RunSpec::new("mixtral", w.clone(), PolicyKind::Static(0)))?;
    let mut spec = RunSpec::new("mixtral", w, PolicyKind::Cascade(CascadeParams::default()));
    spec.max_tokens = ctx.tokens_per_cell * 2; // longer stream
    let (s, run) = ctx.run(&spec)?;
    let mut t = Table::new(
        "Fig 16: per-request utility under Cascade (mixtral, all-3 mix)",
        &["request", "task", "mean utility", "mean K", "tokens"],
    );
    for req in &run.requests {
        let wins = req.utility_windows(16, base.0.mean_iter_s);
        let mu = wins.iter().map(|w| w.utility).sum::<f64>() / wins.len().max(1) as f64;
        let mk = req.iters.iter().map(|r| r.k_chosen as f64).sum::<f64>()
            / req.iters.len().max(1) as f64;
        t.row(vec![
            format!("r{}", req.id),
            req.task.clone(),
            f2(mu),
            f2(mk),
            req.tokens_emitted().to_string(),
        ]);
    }
    t.row(vec![
        "overall".into(),
        "-".into(),
        ratio(base.0.tpot_s / s.tpot_s),
        "-".into(),
        s.tokens.to_string(),
    ]);
    Ok(vec![t])
}

/// Fig. 17: Cascade with EAGLE-lite speculation on Mixtral. Paper shape:
/// static-K avoids slowdowns (higher draft accuracy), K=1 is best static,
/// Cascade matches the best static everywhere.
pub fn fig17(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 17: Mixtral + EAGLE-lite, TPOT speedup vs no-spec",
        &["task", "K=1", "K=2", "K=3", "cascade"],
    );
    for w in Workload::all_seven() {
        let mut row = vec![w.name.clone()];
        for policy in [
            PolicyKind::Static(1),
            PolicyKind::Static(2),
            PolicyKind::Static(3),
            PolicyKind::Cascade(CascadeParams::default()),
        ] {
            let s = ctx.speedup(
                &RunSpec::new("mixtral", w.clone(), policy).with_drafter(DrafterKind::EagleLite),
            )?;
            row.push(ratio(s));
        }
        t.row(row);
    }
    Ok(vec![t])
}

/// Fig. 18: the three optimizations enabled incrementally (Mixtral, 7
/// tasks). Level 0 = static K_start=3, +disable, +back-off, +hill-climb.
pub fn fig18(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 18: Cascade ablation on Mixtral (TPOT speedup vs no-spec)",
        &["task", "none(K=3)", "+disable", "+back-off", "+hill-climb"],
    );
    for w in Workload::all_seven() {
        let mut row = vec![w.name.clone()];
        for level in 0..=3usize {
            let s = ctx.speedup(&RunSpec::new(
                "mixtral",
                w.clone(),
                PolicyKind::Cascade(CascadeParams::ablation(level)),
            ))?;
            row.push(ratio(s));
        }
        t.row(row);
    }
    Ok(vec![t])
}

/// §7.5: sensitivity to (t, S) with T = 4t.
pub fn sensitivity(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "7.5: hyperparameter sensitivity (Mixtral, geomean over 7 tasks)",
        &["t", "S", "geomean speedup"],
    );
    for (trial, set) in [(2usize, 8usize), (4, 16), (8, 32)] {
        let mut vals = Vec::new();
        for w in Workload::all_seven() {
            let s = ctx.speedup(&RunSpec::new(
                "mixtral",
                w,
                PolicyKind::Cascade(CascadeParams::with_phases(trial, set)),
            ))?;
            vals.push(s.ln());
        }
        let geo = (vals.iter().sum::<f64>() / vals.len() as f64).exp();
        t.row(vec![trial.to_string(), set.to_string(), ratio(geo)]);
    }
    Ok(vec![t])
}

/// Coefficient of determination of the y = x predictor (utility predicts
/// speedup 1:1 per Theorem 4.2).
pub fn r_squared(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mean_y = ys.iter().sum::<f64>() / n;
    let ss_tot: f64 = ys.iter().map(|y| (y - mean_y).powi(2)).sum();
    let ss_res: f64 = xs.iter().zip(ys).map(|(x, y)| (y - x).powi(2)).sum();
    1.0 - ss_res / ss_tot
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r_squared_perfect_fit() {
        let xs = [1.0, 2.0, 3.0];
        assert!((r_squared(&xs, &xs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r_squared_poor_fit_lower() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [3.0, 1.0, 2.0];
        assert!(r_squared(&xs, &ys) < 0.5);
    }
}
