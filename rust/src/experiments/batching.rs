//! Batched-serving experiment (extension beyond the paper's single-batch
//! setting): batch=1 vs batch=4 TPOT for static-K vs Cascade, with
//! batch-occupancy and cross-request expert-overlap telemetry.
//!
//! The quantity to watch is the per-iteration routed-expert cost: with the
//! batch-aware cost model it is charged on the expert set de-duplicated
//! across all in-flight requests, so at batch=4 it must grow **sub-linearly**
//! vs batch=1 (cross-request overlap; cf. SP-MoE and the offloading
//! latency-hiding line in PAPERS.md). Runs on the sim backend, whose fused
//! `step_batch` attributes expert ids.

use crate::experiments::runner::ExpCtx;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::Workload;
use anyhow::Result;

const BATCHES: [usize; 2] = [1, 4];

pub fn batch_compare(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Batched serving (sim backend, code+math mix): fused verify with batch-deduplicated expert cost",
        &[
            "model",
            "policy",
            "batch",
            "tokens",
            "TPOT",
            "occupancy",
            "experts/iter dedup",
            "experts/iter summed",
            "overlap saved",
            "expert-cost x (vs b=1)",
        ],
    );
    let workload = Workload::by_name("code+math").expect("known mix");
    for model in ["mixtral", "deepseek"] {
        for policy in [PolicyKind::Static(3), PolicyKind::Cascade(Default::default())] {
            let mut expert_s_b1 = f64::NAN;
            for batch in BATCHES {
                let cfg = ctx.batch_cfg(model, batch);
                let m = ctx.run_batch_cell(cfg, &policy, &workload)?;
                if batch == 1 {
                    expert_s_b1 = m.mean_expert_s();
                }
                let expert_ratio = m.mean_expert_s() / expert_s_b1;
                t.row(vec![
                    model.into(),
                    policy.label(),
                    batch.to_string(),
                    m.run.total_tokens().to_string(),
                    ms(m.tpot_s()),
                    format!("{:.2}", m.mean_occupancy()),
                    format!("{:.1}", m.mean_batch_unique()),
                    format!("{:.1}", m.mean_summed_unique()),
                    format!("{:.1}%", 100.0 * m.overlap_savings()),
                    format!("{expert_ratio:.2}x"),
                ]);
            }
        }
    }
    Ok(vec![t])
}
