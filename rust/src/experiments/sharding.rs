//! Expert-parallel sharding experiment (extension beyond the paper's
//! single-GPU setting): TPOT / verify time / Cascade-K across shard counts
//! and placement strategies.
//!
//! The mechanism under test: sharding the expert set across devices turns
//! the fused verify's expert term into a **max over per-shard deduped
//! loads** (plus an all-to-all), so the speculative expert mass the paper's
//! §2.4 phenomenon charges is partially hidden behind parallel fetch —
//! utility rises, and Cascade should hold speculation on (or pick larger K)
//! at batch sizes where the single-GPU cost made it quit. The placement
//! axis (balanced round-robin vs the co-activation-aware greedy packer)
//! shows that *which* experts share a shard is measurable load-balance
//! quality, not a detail (cf. MoE-Spec's expert budgeting and SP-MoE's
//! placement line in PAPERS.md).

use crate::config::PlacementKind;
use crate::experiments::runner::ExpCtx;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::Workload;
use anyhow::Result;

/// Default shard axis of `figure sharding` (and the sharding bench).
pub const DEFAULT_SHARDS: [usize; 3] = [1, 2, 4];

/// Placement strategies exercised at a given shard count — a single shard
/// has no placement decision. Shared by `figure sharding`, `sweep
/// --shards`, and the bench so their axes cannot drift apart.
pub fn placement_axis(shards: usize) -> &'static [PlacementKind] {
    if shards <= 1 {
        &[PlacementKind::Balanced]
    } else {
        &[PlacementKind::Balanced, PlacementKind::CoActivation]
    }
}

/// Table/JSON label for a placement cell ("-" where placement is moot).
pub fn placement_cell_label(shards: usize, placement: PlacementKind) -> &'static str {
    if shards <= 1 {
        "-"
    } else {
        placement.label()
    }
}

/// One serving run at a (model, policy, shards, placement) cell, through
/// the shared per-cell runner (`ExpCtx::run_batch_cell`).
pub fn run_cell(
    ctx: &mut ExpCtx,
    model: &str,
    policy: &PolicyKind,
    batch: usize,
    shards: usize,
    placement: PlacementKind,
) -> Result<crate::metrics::BatchRunMetrics> {
    let mut cfg = ctx.batch_cfg(model, batch);
    cfg.shards = shards;
    cfg.placement = placement;
    let workload = Workload::by_name("code+math").expect("known mix");
    ctx.run_batch_cell(cfg, policy, &workload)
}

/// The sharding comparison over an explicit shard axis (the CLI's
/// `sweep --shards a,b,c` and `figure sharding` both land here).
pub fn sharding_table(ctx: &mut ExpCtx, shard_counts: &[usize]) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Expert-parallel sharding (sim backend, code+math mix, batch 4): \
         max-over-shards expert cost + all-to-all",
        &[
            "model",
            "policy",
            "shards",
            "placement",
            "tokens",
            "TPOT",
            "verify ms/iter",
            "max-shard experts",
            "imbalance",
            "a2a share",
            "K p50",
        ],
    );
    let batch = 4;
    for model in ["mixtral", "deepseek"] {
        for policy in [PolicyKind::Static(3), PolicyKind::Cascade(Default::default())] {
            for &shards in shard_counts {
                for &placement in placement_axis(shards) {
                    let m = run_cell(ctx, model, &policy, batch, shards, placement)?;
                    t.row(vec![
                        model.into(),
                        policy.label(),
                        shards.to_string(),
                        placement_cell_label(shards, placement).to_string(),
                        m.run.total_tokens().to_string(),
                        ms(m.tpot_s()),
                        format!("{:.2}", 1e3 * m.mean_verify_s()),
                        format!("{:.1}", m.mean_max_shard_unique()),
                        format!("{:.2}", m.mean_shard_imbalance()),
                        format!("{:.1}%", 100.0 * m.alltoall_share()),
                        format!("{:.1}", m.run.k_chosen_p50()),
                    ]);
                }
            }
        }
    }
    Ok(vec![t])
}

/// `figure sharding`: the default 1/2/4-shard axis.
pub fn sharding(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    sharding_table(ctx, &DEFAULT_SHARDS)
}
