//! Open-loop arrivals experiment (extension beyond the paper's closed-loop
//! evaluation): latency-SLO telemetry under bursty load, per admission
//! policy.
//!
//! A closed-loop driver admits a fresh request the instant a slot frees, so
//! offered load always equals service rate and queueing delay / TTFT / tail
//! latency are structurally unobservable. These cells drive the engine
//! **open-loop**: arrivals land on the virtual clock (Poisson or bursty
//! on/off phases, `workload::arrivals`), wait in the admission queue, and
//! enter per the configured [`AdmissionKind`]. The contended cell points a
//! bursty stream at a half-working-set KV pool with LRU eviction — the
//! regime where admission *ordering* matters: under `fcfs`, fresh arrivals
//! grab freed slots and blocks ahead of parked eviction victims, so victims
//! ping-pong (evict → wait → re-prefill → evict again) and their cumulative
//! out-of-service wait balloons; `parked-first` drains victims first, which
//! cuts both the re-prefill thrash and the p95 queueing delay (the ROADMAP's
//! "eviction-aware admission ordering" follow-on, closed here); `edf` admits
//! by `arrival + SLO` deadline. Shared by `figure arrivals`, `sweep --rate`,
//! and the `bench` BENCH_arrivals.json emitter so the axes can never drift.

use crate::config::{AdmissionKind, EvictionKind};
use crate::coordinator::scheduler::{Budget, Scheduler};
use crate::experiments::preemption::constrained_pool_blocks;
use crate::experiments::runner::ExpCtx;
use crate::metrics::BatchRunMetrics;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::arrivals::{ArrivalKind, ArrivalProcess};
use crate::workload::{RequestStream, Workload};
use anyhow::Result;

/// Admission policies on the arrivals axis.
pub const ADMISSIONS: [AdmissionKind; 3] =
    [AdmissionKind::Fcfs, AdmissionKind::ParkedFirst, AdmissionKind::Edf];

/// One open-loop serving cell.
pub struct ArrivalCell {
    pub admission: AdmissionKind,
    pub arrivals: ArrivalKind,
    /// KV pool size in blocks (0 = uncontended auto sizing; contention is
    /// what makes admission ordering visible).
    pub pool_blocks: usize,
    /// Eviction policy (victims must exist for parked ordering to matter).
    pub eviction: EvictionKind,
    /// Per-request TTFT SLO on the virtual clock (feeds edf + goodput).
    pub slo_s: f64,
    /// Per-request output cap (short requests → enough completions for
    /// meaningful percentiles within the budget).
    pub max_new: usize,
    /// Output-token budget of the cell.
    pub tokens: usize,
}

/// Requests per contended cell the budget is sized for.
const CELL_REQUESTS: usize = 12;

/// The canonical contended cell: bursty arrivals at `rate` (mean req/s)
/// into a half-working-set KV pool with LRU eviction — the preemption
/// experiment's pool sizing applied to this cell's own request shape.
pub fn contended_cell(admission: AdmissionKind, rate: f64, seed: u64) -> ArrivalCell {
    let max_new = 120usize;
    let sample = RequestStream::new(cell_workload(), seed, max_new).take(8);
    ArrivalCell {
        admission,
        arrivals: ArrivalKind::bursty(rate),
        pool_blocks: constrained_pool_blocks(&sample, 4),
        eviction: EvictionKind::Lru,
        slo_s: 0.5,
        max_new,
        tokens: CELL_REQUESTS * max_new,
    }
}

fn cell_workload() -> Workload {
    Workload::by_name("code+math").expect("known mix")
}

/// Serve one open-loop cell on the sim backend at batch 4.
pub fn run_cell(
    ctx: &ExpCtx,
    model: &str,
    policy: &PolicyKind,
    cell: &ArrivalCell,
) -> Result<BatchRunMetrics> {
    let mut cfg = ctx.batch_cfg(model, 4);
    cfg.max_new_tokens = cell.max_new;
    cfg.kv_pool_blocks = cell.pool_blocks;
    cfg.eviction = cell.eviction;
    // Generous cap, as in the preemption cells: these measure ordering
    // quality, not cap exhaustion.
    cfg.max_preemptions_per_req = 64;
    cfg.admission = cell.admission;
    cfg.slo_s = cell.slo_s;
    let mut engine = ctx.batch_engine(cfg, policy)?;
    let stream = RequestStream::new(cell_workload(), ctx.seed, cell.max_new);
    let arrivals = ArrivalProcess::new(cell.arrivals.clone(), stream, ctx.seed)?;
    let mut sched = Scheduler::with_arrivals(
        arrivals,
        Budget { max_tokens: cell.tokens, max_requests: 10_000 },
    );
    sched.run_batched(&mut engine)
}

fn pct(m: &BatchRunMetrics, p: f64) -> (f64, f64, f64) {
    (m.run.ttft_percentile(p), m.run.queue_wait_percentile(p), m.run.e2e_percentile(p))
}

/// `figure arrivals`: TTFT / queueing-delay / E2E percentiles and SLO
/// goodput per admission policy, under bursty arrivals into a contended
/// pool (sim backend, batch 4).
pub fn arrivals(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let rate = 2.0;
    let probe = contended_cell(AdmissionKind::Fcfs, rate, ctx.seed);
    let mut t = Table::new(
        format!(
            "Open-loop arrivals (sim backend, code+math mix, batch 4): \
             {} into a {}-block pool (eviction=lru), SLO {:.0}ms TTFT",
            probe.arrivals.label(),
            probe.pool_blocks,
            1e3 * probe.slo_s
        ),
        &[
            "policy",
            "admission",
            "reqs",
            "tokens",
            "TTFT p50",
            "TTFT p95",
            "TTFT p99",
            "queue p50",
            "queue p95",
            "queue p99",
            "E2E p95",
            "goodput",
            "evict/readmit",
            "depth",
            "idle",
        ],
    );
    for policy in [PolicyKind::Static(3), PolicyKind::Cascade(Default::default())] {
        for admission in ADMISSIONS {
            let cell = contended_cell(admission, rate, ctx.seed);
            let m = run_cell(ctx, "mixtral", &policy, &cell)?;
            let (t50, q50, _) = pct(&m, 0.50);
            let (t95, q95, e95) = pct(&m, 0.95);
            let (t99, q99, _) = pct(&m, 0.99);
            t.row(vec![
                policy.label(),
                admission.label().into(),
                m.run.requests.len().to_string(),
                m.run.total_tokens().to_string(),
                ms(t50),
                ms(t95),
                ms(t99),
                ms(q50),
                ms(q95),
                ms(q99),
                ms(e95),
                format!("{:.0}%", 100.0 * m.run.slo_goodput(cell.slo_s)),
                format!("{}/{}", m.evictions(), m.readmissions()),
                format!("{:.1}", m.mean_queue_depth()),
                format!("{:.0}%", 100.0 * m.slot_idle_fraction()),
            ]);
        }
    }
    Ok(vec![t])
}

/// `sweep --rate a,b,c`: Poisson saturation sweep — latency and occupancy
/// vs offered rate on an uncontended pool (fcfs admission). Low rates show
/// idle slots (the state a closed loop cannot express); high rates show
/// the queue building.
pub fn rate_sweep_table(ctx: &mut ExpCtx, rates: &[f64]) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Open-loop rate sweep (sim backend, code+math mix, batch 4, \
         poisson arrivals, fcfs admission, uncontended pool)",
        &[
            "rate/s",
            "reqs",
            "tokens",
            "duration s",
            "TPOT",
            "TTFT p50",
            "TTFT p95",
            "queue p95",
            "depth",
            "idle",
        ],
    );
    for &rate in rates {
        anyhow::ensure!(rate > 0.0, "--rate entries must be positive");
        let cell = ArrivalCell {
            admission: AdmissionKind::Fcfs,
            arrivals: ArrivalKind::Poisson { rate },
            pool_blocks: 0,
            eviction: EvictionKind::Off,
            slo_s: 0.0,
            max_new: 120,
            tokens: ctx.tokens_per_cell,
        };
        let m = run_cell(ctx, "mixtral", &PolicyKind::Static(3), &cell)?;
        let (t50, _, _) = pct(&m, 0.50);
        let (t95, q95, _) = pct(&m, 0.95);
        t.row(vec![
            format!("{rate:.2}"),
            m.run.requests.len().to_string(),
            m.run.total_tokens().to_string(),
            format!("{:.2}", m.clock_s),
            ms(m.tpot_s()),
            ms(t50),
            ms(t95),
            ms(q95),
            format!("{:.1}", m.mean_queue_depth()),
            format!("{:.0}%", 100.0 * m.slot_idle_fraction()),
        ]);
    }
    Ok(vec![t])
}
