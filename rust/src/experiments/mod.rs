//! Experiment harnesses: one per table/figure in the paper's evaluation
//! (see DESIGN.md §4 for the index). Each harness returns `Table`s that are
//! printed and optionally written to `results/` as CSV.

pub mod arrivals;
pub mod batching;
pub mod faults;
pub mod figures;
pub mod pipeline;
pub mod preemption;
pub mod prefix;
pub mod related;
pub mod runner;
pub mod sharding;

pub use runner::{BackendKind, ExpCtx, RunSpec};

use crate::util::table::Table;
use anyhow::Result;

/// A figure/table reproduction: id, paper caption, and the harness.
pub struct Experiment {
    pub id: &'static str,
    pub caption: &'static str,
    pub run: fn(&mut ExpCtx) -> Result<Vec<Table>>,
}

/// Registry of every reproduced table/figure.
pub fn all() -> Vec<Experiment> {
    vec![
        Experiment { id: "table1", caption: "Model zoo (Table 1)", run: figures::table1 },
        Experiment {
            id: "fig1c",
            caption: "Static-K n-gram speculation on Mixtral (Fig. 1c)",
            run: figures::fig1c,
        },
        Experiment {
            id: "fig4",
            caption: "Dense vs MoE: TPOT/ETR and iteration breakdown, K=1..7 (Fig. 4)",
            run: figures::fig4,
        },
        Experiment {
            id: "fig5",
            caption: "TPOT across 5 MoEs x 7 tasks x K in {1,2,3} (Fig. 5)",
            run: figures::fig5,
        },
        Experiment {
            id: "fig6",
            caption: "Iteration-level ETR and cost, Phi + extract (Fig. 6)",
            run: figures::fig6,
        },
        Experiment {
            id: "fig7",
            caption: "Per-request utility traces (Fig. 7)",
            run: figures::fig7,
        },
        Experiment {
            id: "fig8",
            caption: "Speedup vs utility, 120 points (Fig. 8, Theorem 4.2)",
            run: figures::fig8,
        },
        Experiment {
            id: "fig13",
            caption: "HEADLINE: Cascade vs static-K, 5 MoEs x 7 tasks (Fig. 13)",
            run: figures::fig13,
        },
        Experiment {
            id: "fig15",
            caption: "Utility trace: Mixtral+math, static K=3 vs Cascade (Fig. 15)",
            run: figures::fig15,
        },
        Experiment {
            id: "fig16",
            caption: "Utility trace: Mixtral + all-3 mix with Cascade (Fig. 16)",
            run: figures::fig16,
        },
        Experiment {
            id: "fig17",
            caption: "Cascade with EAGLE-lite speculation on Mixtral (Fig. 17)",
            run: figures::fig17,
        },
        Experiment {
            id: "fig18",
            caption: "Ablation: disable / back-off / hill-climb (Fig. 18)",
            run: figures::fig18,
        },
        Experiment {
            id: "sens",
            caption: "Hyperparameter sensitivity t/S (paper 7.5)",
            run: figures::sensitivity,
        },
        Experiment {
            id: "related",
            caption: "Lookahead/Medusa cost analysis (paper 8.1)",
            run: related::related,
        },
        Experiment {
            id: "batch",
            caption: "EXTENSION: continuous batching, batch-deduplicated expert cost (sim)",
            run: batching::batch_compare,
        },
        Experiment {
            id: "pipeline",
            caption: "EXTENSION: pipelined drafting, draft(i+1) under verify(i) (sim)",
            run: pipeline::pipeline_compare,
        },
        Experiment {
            id: "sharding",
            caption: "EXTENSION: expert-parallel sharding, max-over-shards verify cost (sim)",
            run: sharding::sharding,
        },
        Experiment {
            id: "preemption",
            caption: "EXTENSION: KV-pool preemption, throughput vs pool size with/without eviction (sim)",
            run: preemption::preemption,
        },
        Experiment {
            id: "prefix",
            caption: "EXTENSION: prefix sharing, TTFT vs template share ratio under COW KV reuse (sim)",
            run: prefix::prefix,
        },
        Experiment {
            id: "arrivals",
            caption: "EXTENSION: open-loop arrivals, TTFT/queueing-delay/E2E percentiles per admission policy (sim)",
            run: arrivals::arrivals,
        },
        Experiment {
            id: "faults",
            caption: "EXTENSION: fault injection, SLO goodput under chaos with the degradation controller on vs off (sim)",
            run: faults::faults,
        },
    ]
}

pub fn by_id(id: &str) -> Option<Experiment> {
    all().into_iter().find(|e| e.id == id)
}
