//! Shared experiment runner: build an engine for a (model, task, policy,
//! backend) cell, serve a token budget, summarize.
//!
//! Engines (and their compiled PJRT executables) are cached per model so a
//! figure touching 5 models x 4 policies compiles each variant once.

use crate::config::{DrafterKind, EngineConfig};
use crate::coordinator::batch::BatchEngine;
use crate::coordinator::engine::{Engine, RunSummary};
use crate::coordinator::scheduler::{Budget, Scheduler};
use crate::metrics::{BatchRunMetrics, RunMetrics};
use crate::models::Registry;
use crate::spec::policy::PolicyKind;
use crate::workload::{RequestStream, Workload};
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Which backend executes the target model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO through PJRT (the production path).
    Real,
    /// Trace-level simulation (fast sweeps; cross-validated against Real).
    Sim,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "real" => Ok(BackendKind::Real),
            "sim" => Ok(BackendKind::Sim),
            other => anyhow::bail!("unknown backend {other:?} (want real|sim)"),
        }
    }
}

/// One experiment cell.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub model: String,
    pub workload: Workload,
    pub policy: PolicyKind,
    pub drafter: DrafterKind,
    pub max_tokens: usize,
    pub seed: u64,
}

impl RunSpec {
    pub fn new(model: &str, workload: Workload, policy: PolicyKind) -> Self {
        Self {
            model: model.into(),
            workload,
            policy,
            drafter: DrafterKind::Ngram,
            max_tokens: 0, // 0 = use ctx default
            seed: 0xCA5CADE,
        }
    }

    pub fn with_drafter(mut self, d: DrafterKind) -> Self {
        self.drafter = d;
        self
    }
}

/// Experiment context: registry + global knobs from the CLI.
pub struct ExpCtx {
    pub registry: Registry,
    pub backend: BackendKind,
    /// Output-token budget per cell (CLI `--tokens`).
    pub tokens_per_cell: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Shared PJRT client so each figure pays client start-up once.
    client: Option<xla::PjRtClient>,
    /// Memoized no-speculation baselines: (model, workload, drafter, tokens)
    /// -> baseline TPOT.
    baseline_cache: BTreeMap<(String, String, DrafterKind, usize), f64>,
    /// Shared compiled runtimes: one PJRT compile + weight upload per model
    /// per process (engines share; request state is per-engine).
    runtimes: BTreeMap<String, crate::coordinator::backend::SharedRuntime>,
}

impl ExpCtx {
    pub fn new(registry: Registry, backend: BackendKind, tokens_per_cell: usize) -> Self {
        Self {
            registry,
            backend,
            tokens_per_cell,
            max_new_tokens: 200,
            seed: 0xCA5CADE,
            client: None,
            baseline_cache: BTreeMap::new(),
            runtimes: BTreeMap::new(),
        }
    }

    /// Get (or build) the shared runtime for `model`.
    fn runtime(&mut self, model: &str) -> Result<crate::coordinator::backend::SharedRuntime> {
        if let Some(rt) = self.runtimes.get(model) {
            return Ok(rt.clone());
        }
        let client = self.client()?;
        let rt = crate::runtime::ModelRuntime::with_client(&self.registry, model, client)
            .with_context(|| format!("loading model {model}"))?;
        let rt = std::rc::Rc::new(std::cell::RefCell::new(rt));
        self.runtimes.insert(model.to_string(), rt.clone());
        Ok(rt)
    }

    fn client(&mut self) -> Result<xla::PjRtClient> {
        if self.client.is_none() {
            self.client = Some(
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT client: {e:?}"))?,
            );
        }
        Ok(self.client.as_ref().unwrap().clone())
    }

    /// Build an engine for a spec.
    pub fn engine(&mut self, spec: &RunSpec) -> Result<Engine> {
        let cfg = EngineConfig {
            model: spec.model.clone(),
            drafter: spec.drafter,
            max_new_tokens: self.max_new_tokens,
            seed: spec.seed,
            ..EngineConfig::default()
        };
        let policy = spec.policy.build();
        match self.backend {
            BackendKind::Sim => Engine::sim(&self.registry, cfg, policy),
            BackendKind::Real => {
                let runtime = self.runtime(&cfg.model)?;
                let (paper, mini_layers) = {
                    let rt = runtime.borrow();
                    (rt.model.paper.clone(), rt.model.mini.layers)
                };
                let cost = crate::cost::GpuCostModel::new(paper, mini_layers);
                let backend = Box::new(crate::coordinator::backend::RealBackend::shared(
                    runtime,
                    cfg.guide_strength,
                    cfg.seed,
                ));
                let drafter = match cfg.drafter {
                    DrafterKind::Ngram => crate::coordinator::engine::EngineDrafter::Ngram(
                        crate::spec::NgramDrafter::new(cfg.ngram_min, cfg.ngram_max),
                    ),
                    DrafterKind::EagleLite => {
                        let draft_rt = self.runtime("draft")?;
                        crate::coordinator::engine::EngineDrafter::Eagle(
                            crate::coordinator::eagle::EagleLite::shared(
                                draft_rt,
                                cfg.guide_strength,
                                cfg.seed ^ 0xE1,
                            ),
                        )
                    }
                };
                Ok(Engine::new(cfg, backend, drafter, cost, policy))
            }
        }
    }

    /// Run one cell: serve requests until the token budget is spent.
    pub fn run(&mut self, spec: &RunSpec) -> Result<(RunSummary, RunMetrics)> {
        let budget = Budget {
            max_tokens: if spec.max_tokens > 0 { spec.max_tokens } else { self.tokens_per_cell },
            max_requests: 10_000,
        };
        let mut engine = self.engine(spec)?;
        let stream = RequestStream::new(spec.workload.clone(), spec.seed, self.max_new_tokens);
        let mut sched = Scheduler::new(stream, budget);
        let run = sched.run(&mut engine)?;
        let summary = RunSummary::from_run(
            &spec.model,
            &spec.workload.name,
            &spec.policy.label(),
            &run,
        );
        Ok((summary, run))
    }

    /// Batched-engine config for one experiment cell, carrying the ctx's
    /// seed and per-request token cap — the base every batched experiment
    /// (batching / pipeline / sharding / preemption / arrivals) builds on,
    /// so their cells cannot drift apart on the shared knobs.
    pub fn batch_cfg(&self, model: &str, batch: usize) -> EngineConfig {
        EngineConfig {
            model: model.into(),
            max_batch: batch,
            max_new_tokens: self.max_new_tokens,
            seed: self.seed,
            ..EngineConfig::default()
        }
    }

    /// Sim-backend batched engine for a cell config.
    pub fn batch_engine(&self, cfg: EngineConfig, policy: &PolicyKind) -> Result<BatchEngine> {
        BatchEngine::sim(&self.registry, cfg, policy.clone())
    }

    /// One batched serving cell: a fresh closed-loop stream of `workload`
    /// served until the ctx token budget is spent. The per-cell runner the
    /// batching, pipeline, and sharding experiments (and the bench
    /// emitters) share — previously each re-grew its own copy.
    pub fn run_batch_cell(
        &self,
        cfg: EngineConfig,
        policy: &PolicyKind,
        workload: &Workload,
    ) -> Result<BatchRunMetrics> {
        let mut engine = self.batch_engine(cfg, policy)?;
        let stream = RequestStream::new(workload.clone(), self.seed, self.max_new_tokens);
        let mut sched = Scheduler::new(
            stream,
            Budget { max_tokens: self.tokens_per_cell, max_requests: 10_000 },
        );
        sched.run_batched(&mut engine)
    }

    /// Baseline (K=0) TPOT for a (model, workload, drafter) cell, memoized.
    pub fn baseline_tpot(&mut self, spec: &RunSpec) -> Result<f64> {
        let key = (
            spec.model.clone(),
            spec.workload.name.clone(),
            spec.drafter,
            spec.max_tokens,
        );
        if let Some(&t) = self.baseline_cache.get(&key) {
            return Ok(t);
        }
        let base = RunSpec { policy: PolicyKind::Static(0), ..spec.clone() };
        let (b, _) = self.run(&base)?;
        self.baseline_cache.insert(key, b.tpot_s);
        Ok(b.tpot_s)
    }

    /// TPOT speedup of `spec` relative to the no-speculation baseline of the
    /// same (model, workload): the y-axis of most paper figures.
    pub fn speedup(&mut self, spec: &RunSpec) -> Result<f64> {
        let (s, _) = self.run(spec)?;
        let base = self.baseline_tpot(spec)?;
        Ok(base / s.tpot_s)
    }
}
