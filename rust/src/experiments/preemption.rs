//! Preemption/eviction experiment (extension beyond the paper's
//! single-batch setting): completed-request throughput under an
//! oversubscribed shared KV pool, with and without victim eviction.
//!
//! The serving regime the north star demands — heavy traffic into a fixed
//! pool — makes one request's speculative lookahead crowd out another's
//! decoding. With `eviction = off` an oversubscribed pool eventually
//! deadlocks (every in-flight request stuck at a block boundary, nothing
//! freeing blocks) and the run aborts with the deadlock error; the rows
//! here report what completed before the stall. With a victim policy
//! (`lru` / `most-lookahead` / `cost-aware`, see `coordinator::eviction`)
//! the engine preempts, re-prefills on re-admission, and completes every
//! request — at the honest price of the re-prefill time, charged into
//! `IterCost::reprefill_s` (the "thrash" column). The interesting
//! comparison is completed-request throughput at the constrained pool:
//! eviction strictly beats the deadlocking baseline, and the policies
//! differ in how much thrash they pay for it.

use crate::config::EvictionKind;
use crate::coordinator::batch::KV_BLOCK;
use crate::experiments::runner::ExpCtx;
use crate::metrics::BatchRunMetrics;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::{Request, RequestStream, Workload};
use anyhow::Result;

/// Victim policies on the experiment axis (off = deadlock baseline).
pub const EVICTIONS: [EvictionKind; 4] = [
    EvictionKind::Off,
    EvictionKind::Lru,
    EvictionKind::MostLookahead,
    EvictionKind::CostAware,
];

/// Deterministic request list for the preemption cells: long generations
/// so a constrained pool genuinely thrashes.
pub fn cell_requests(n: usize, max_new: usize, seed: u64) -> Vec<Request> {
    let w = Workload::by_name("code+math").expect("known mix");
    RequestStream::new(w, seed, max_new).take(n)
}

/// Pool size of roughly **half the batch's working set**: the `batch`
/// largest request spans (prompt + full decode, block-rounded), halved.
/// Small enough that the off baseline deadlocks, large enough that any
/// single request always fits (the engine additionally clamps to one full
/// window).
pub fn constrained_pool_blocks(reqs: &[Request], batch: usize) -> usize {
    let span = |r: &Request| (r.prompt.len() + 1 + r.max_new_tokens).div_ceil(KV_BLOCK) + 1;
    let mut spans: Vec<usize> = reqs.iter().map(span).collect();
    spans.sort_unstable_by(|a, b| b.cmp(a));
    let working: usize = spans.iter().take(batch.max(1)).sum();
    (working / 2).max(1)
}

/// Outcome of one serving cell: the run's metrics (partial when the pool
/// deadlocked — only requests completed before the stall), the deadlock
/// message when the run aborted, and the pool's victim count.
pub struct CellOutcome {
    pub metrics: BatchRunMetrics,
    pub deadlock: Option<String>,
    pub total_evicted: u64,
}

impl CellOutcome {
    /// Completed-request throughput: tokens of *completed* requests per
    /// simulated second of the whole run (deadlocked runs pay for the
    /// stranded iterations without harvesting their requests).
    pub fn completed_tokens_per_s(&self) -> f64 {
        let time: f64 = self.metrics.iters.iter().map(|r| r.cost.total()).sum();
        if time == 0.0 {
            return 0.0;
        }
        self.metrics.run.total_tokens() as f64 / time
    }
}

/// Serve `reqs` to completion (or deadlock) on the sim backend with the
/// given pool size (0 = uncontended auto sizing) and eviction policy.
/// Shared by `figure preemption` and the `bench` JSON emitter so the two
/// can never drift.
pub fn run_cell(
    ctx: &mut ExpCtx,
    model: &str,
    policy: &PolicyKind,
    batch: usize,
    pool_blocks: usize,
    eviction: EvictionKind,
    reqs: &[Request],
) -> Result<CellOutcome> {
    let mut cfg = ctx.batch_cfg(model, batch);
    cfg.kv_pool_blocks = pool_blocks;
    cfg.eviction = eviction;
    // Generous cap: the cells measure policy quality, not cap exhaustion
    // (rust/tests/preemption.rs covers the cap bound).
    cfg.max_preemptions_per_req = 64;
    let mut engine = ctx.batch_engine(cfg, policy)?;
    match engine.serve_all(reqs) {
        Ok(metrics) => Ok(CellOutcome {
            metrics,
            deadlock: None,
            total_evicted: engine.pool.total_evicted,
        }),
        Err(e) => {
            let msg = e.to_string();
            // Only the documented stall is a reportable outcome; anything
            // else is a real failure.
            anyhow::ensure!(msg.contains("deadlock"), "unexpected serving failure: {msg}");
            Ok(CellOutcome {
                metrics: engine.finish(),
                deadlock: Some(msg),
                total_evicted: engine.pool.total_evicted,
            })
        }
    }
}

/// The `figure preemption` table: throughput vs pool size with and without
/// eviction, at batch 4 on the sim backend.
pub fn preemption(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let batch = 4usize;
    let reqs = cell_requests(8, ctx.max_new_tokens, ctx.seed);
    let constrained = constrained_pool_blocks(&reqs, batch);
    let mut t = Table::new(
        format!(
            "Preemption (sim backend, code+math mix, batch {batch}): \
             completed-request throughput vs pool size; constrained pool = \
             {constrained} blocks (~half the working set)"
        ),
        &[
            "policy",
            "pool",
            "eviction",
            "done",
            "tokens",
            "TPOT",
            "tok/s done",
            "evict",
            "readmit",
            "reprefill ms",
            "thrash",
            "status",
        ],
    );
    for policy in [PolicyKind::Static(3), PolicyKind::Cascade(Default::default())] {
        for (pool_label, pool_blocks, evictions) in [
            // Uncontended baseline: eviction is inert, one row suffices.
            ("auto", 0usize, &EVICTIONS[..1]),
            ("half", constrained, &EVICTIONS[..]),
        ] {
            for &eviction in evictions {
                let out =
                    run_cell(ctx, "mixtral", &policy, batch, pool_blocks, eviction, &reqs)?;
                let m = &out.metrics;
                t.row(vec![
                    policy.label(),
                    pool_label.into(),
                    eviction.label().into(),
                    format!("{}/{}", m.run.requests.len(), reqs.len()),
                    m.run.total_tokens().to_string(),
                    ms(m.tpot_s()),
                    format!("{:.1}", out.completed_tokens_per_s()),
                    m.evictions().to_string(),
                    m.readmissions().to_string(),
                    format!("{:.2}", 1e3 * m.reprefill_s()),
                    format!("{:.1}%", 100.0 * m.thrash_fraction()),
                    if out.deadlock.is_some() { "deadlock".into() } else { "ok".to_string() },
                ]);
            }
        }
    }
    Ok(vec![t])
}
