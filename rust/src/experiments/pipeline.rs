//! Pipelined-drafting experiment (extension beyond the paper's serial
//! worker): serial vs pipelined TPOT across batch sizes, with bubble and
//! hidden-drafting telemetry.
//!
//! The drafting pipeline overlaps draft(i+1) with verify(i) — SpecInfer's
//! tree-parallel pipelining and vLLM's decoupled draft/score workers in
//! PAPERS.md follow the same discipline. Token output is bit-identical to
//! serial (losslessness is tested in `rust/tests/batching.rs`); what this
//! table shows is the *timing* effect: drafting cost disappears from the
//! simulated clock wherever the full-acceptance prediction held, and the
//! bubble fraction shows where it did not. With the static-K policies the
//! speedup is pure overlap; Cascade rows additionally shift K decisions,
//! because utility is measured against pipeline-true (and marginal)
//! per-request cost.

use crate::experiments::runner::ExpCtx;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::Workload;
use anyhow::Result;

const BATCHES: [usize; 3] = [1, 2, 4];

pub fn pipeline_compare(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Pipelined drafting (sim backend, code+math mix): draft(i+1) overlapped with verify(i)",
        &[
            "model",
            "policy",
            "batch",
            "mode",
            "tokens",
            "TPOT",
            "speedup",
            "bubble",
            "hidden draft ms",
            "recomputes",
        ],
    );
    let workload = Workload::by_name("code+math").expect("known mix");
    for model in ["mixtral", "deepseek"] {
        for policy in [PolicyKind::Static(3), PolicyKind::Cascade(Default::default())] {
            for batch in BATCHES {
                let mut tpot_serial = f64::NAN;
                for pipeline in [false, true] {
                    let mut cfg = ctx.batch_cfg(model, batch);
                    cfg.pipeline = pipeline;
                    let m = ctx.run_batch_cell(cfg, &policy, &workload)?;
                    let tpot = m.tpot_s();
                    if !pipeline {
                        tpot_serial = tpot;
                    }
                    t.row(vec![
                        model.into(),
                        policy.label(),
                        batch.to_string(),
                        if pipeline { "pipelined".into() } else { "serial".to_string() },
                        m.run.total_tokens().to_string(),
                        ms(tpot),
                        format!("{:.3}x", tpot_serial / tpot),
                        format!("{:.1}%", 100.0 * m.bubble_fraction()),
                        format!("{:.2}", 1e3 * m.draft_hidden_s()),
                        m.draft_recomputes().to_string(),
                    ]);
                }
            }
        }
    }
    Ok(vec![t])
}
