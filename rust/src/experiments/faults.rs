//! Fault-injection & graceful-degradation experiment (extension beyond the
//! paper's fault-free evaluation): goodput under deterministic chaos, with
//! the degradation controller on vs off.
//!
//! Every cell is the arrivals experiment's contended open-loop shape —
//! bursty arrivals into a half-working-set KV pool with LRU eviction, a
//! 500 ms TTFT SLO — plus a [`FaultPlan`] scheduled on the virtual clock
//! (stragglers, stalls, shard kills, pool shrinks; rust/docs/faults.md)
//! and 2 expert-parallel shards so shard-scoped faults have a topology to
//! act on. The headline comparison is the chaos plan (one of everything)
//! served with `--controller off` vs `adaptive`: the controller cannot
//! un-fail hardware, but by throttling speculation under pressure and
//! shedding unmeetable arrivals it keeps the SLO-goodput slowdown bounded.
//! Faults and degradation move time and scheduling, never token values
//! (rust/tests/chaos.rs), so the goodput numbers are comparable
//! request-for-request. Shared by `figure faults` and the `bench`
//! BENCH_faults.json emitter so the axes can never drift.
//!
//! [`FaultPlan`]: crate::coordinator::faults::FaultPlan

use crate::config::{AdmissionKind, ControllerKind, EvictionKind};
use crate::coordinator::faults::BUILTIN_PLANS;
use crate::coordinator::scheduler::{Budget, Scheduler};
use crate::experiments::preemption::constrained_pool_blocks;
use crate::experiments::runner::ExpCtx;
use crate::metrics::BatchRunMetrics;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::arrivals::{ArrivalKind, ArrivalProcess};
use crate::workload::{RequestStream, Workload};
use anyhow::Result;

/// One fault-injection serving cell.
pub struct FaultCell {
    /// `--faults` spec (`off`, a builtin plan name, or inline clauses).
    pub faults: String,
    pub controller: ControllerKind,
    pub arrivals: ArrivalKind,
    /// Half-working-set pool (contention is what the controller manages).
    pub pool_blocks: usize,
    pub eviction: EvictionKind,
    /// TTFT SLO on the virtual clock (goodput + shedding + EDF slack).
    pub slo_s: f64,
    pub max_new: usize,
    /// Output-token budget of the cell.
    pub tokens: usize,
}

/// Requests per cell the budget is sized for (matches the arrivals cells).
const CELL_REQUESTS: usize = 12;

/// The canonical chaos cell: the arrivals experiment's contended shape
/// with a fault plan layered on top.
pub fn chaos_cell(faults: &str, controller: ControllerKind, seed: u64) -> FaultCell {
    let max_new = 120usize;
    let sample = RequestStream::new(cell_workload(), seed, max_new).take(8);
    FaultCell {
        faults: faults.to_string(),
        controller,
        arrivals: ArrivalKind::bursty(2.0),
        pool_blocks: constrained_pool_blocks(&sample, 4),
        eviction: EvictionKind::Lru,
        slo_s: 0.5,
        max_new,
        tokens: CELL_REQUESTS * max_new,
    }
}

fn cell_workload() -> Workload {
    Workload::by_name("code+math").expect("known mix")
}

/// Serve one fault cell on the sim backend at batch 4 with 2 expert
/// shards (shard-scoped faults need a topology to act on).
pub fn run_cell(
    ctx: &ExpCtx,
    model: &str,
    policy: &PolicyKind,
    cell: &FaultCell,
) -> Result<BatchRunMetrics> {
    let mut cfg = ctx.batch_cfg(model, 4);
    cfg.max_new_tokens = cell.max_new;
    cfg.kv_pool_blocks = cell.pool_blocks;
    cfg.eviction = cell.eviction;
    cfg.max_preemptions_per_req = 64;
    cfg.admission = AdmissionKind::Edf;
    cfg.slo_s = cell.slo_s;
    cfg.shards = 2;
    cfg.faults = cell.faults.clone();
    cfg.controller = cell.controller;
    let mut engine = ctx.batch_engine(cfg, policy)?;
    let stream = RequestStream::new(cell_workload(), ctx.seed, cell.max_new);
    let arrivals = ArrivalProcess::new(cell.arrivals.clone(), stream, ctx.seed)?;
    let mut sched = Scheduler::with_arrivals(
        arrivals,
        Budget { max_tokens: cell.tokens, max_requests: 10_000 },
    );
    sched.run_batched(&mut engine)
}

/// `figure faults`: SLO goodput, latency tails, and fault telemetry for
/// every builtin plan (plus fault-free), controller off vs adaptive.
pub fn faults(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let probe = chaos_cell("off", ControllerKind::Off, ctx.seed);
    let mut t = Table::new(
        format!(
            "Fault injection (sim backend, code+math mix, batch 4, 2 shards): \
             {} into a {}-block pool (eviction=lru, admission=edf), SLO {:.0}ms TTFT",
            probe.arrivals.label(),
            probe.pool_blocks,
            1e3 * probe.slo_s
        ),
        &[
            "faults",
            "controller",
            "reqs",
            "tokens",
            "TPOT",
            "TTFT p95",
            "E2E p99",
            "goodput",
            "shed",
            "events",
            "stall ms",
            "degraded",
            "recovery s",
        ],
    );
    let policy = PolicyKind::Static(3);
    let mut plans: Vec<&str> = vec!["off"];
    plans.extend(BUILTIN_PLANS.iter().map(|(name, _)| *name));
    for plan in plans {
        for controller in [ControllerKind::Off, ControllerKind::Adaptive] {
            let cell = chaos_cell(plan, controller, ctx.seed);
            let m = run_cell(ctx, "mixtral", &policy, &cell)?;
            t.row(vec![
                plan.into(),
                controller.label().into(),
                m.run.requests.len().to_string(),
                m.run.total_tokens().to_string(),
                ms(m.tpot_s()),
                ms(m.run.ttft_percentile(0.95)),
                ms(m.run.e2e_percentile(0.99)),
                format!("{:.0}%", 100.0 * m.run.slo_goodput(cell.slo_s)),
                m.sheds.to_string(),
                m.fault_events.to_string(),
                format!("{:.1}", 1e3 * m.stall_s()),
                format!("{:.0}%", 100.0 * m.degraded_fraction()),
                format!("{:.2}", m.recovery_s),
            ]);
        }
    }
    Ok(vec![t])
}
