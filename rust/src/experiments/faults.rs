//! Fault-injection & graceful-degradation experiment (extension beyond the
//! paper's fault-free evaluation): goodput under deterministic chaos, with
//! the degradation controller on vs off.
//!
//! Every cell is the arrivals experiment's contended open-loop shape —
//! bursty arrivals into a half-working-set KV pool with LRU eviction, a
//! 500 ms TTFT SLO — plus a [`FaultPlan`] scheduled on the virtual clock
//! (stragglers, stalls, shard kills, pool shrinks; rust/docs/faults.md)
//! and 2 expert-parallel shards so shard-scoped faults have a topology to
//! act on. The headline comparison is the chaos plan (one of everything)
//! served with `--controller off` vs `adaptive`: the controller cannot
//! un-fail hardware, but by throttling speculation under pressure and
//! shedding unmeetable arrivals it keeps the SLO-goodput slowdown bounded.
//! Faults and degradation move time and scheduling, never token values
//! (rust/tests/chaos.rs), so the goodput numbers are comparable
//! request-for-request. Shared by `figure faults` and the `bench`
//! BENCH_faults.json emitter so the axes can never drift.
//!
//! [`FaultPlan`]: crate::coordinator::faults::FaultPlan

use crate::config::{AdmissionKind, ControllerKind, EvictionKind};
use crate::coordinator::batch::PROCESS_HORIZON_S;
use crate::coordinator::faults::{FaultPlan, FaultProcess, BUILTIN_PLANS};
use crate::coordinator::scheduler::{Budget, Scheduler};
use crate::experiments::preemption::constrained_pool_blocks;
use crate::experiments::runner::ExpCtx;
use crate::metrics::BatchRunMetrics;
use crate::spec::policy::PolicyKind;
use crate::util::table::{ms, Table};
use crate::workload::arrivals::{ArrivalKind, ArrivalProcess};
use crate::workload::{RequestStream, Workload};
use anyhow::Result;

/// One fault-injection serving cell.
pub struct FaultCell {
    /// `--faults` spec (`off`, a builtin plan name, or inline clauses).
    pub faults: String,
    /// `--fault-process` spec (`off` or `mtbf=<s>,mttr=<s>,kind=<k>`),
    /// materialized seed-deterministically by the engine and merged into
    /// the plan above.
    pub fault_process: String,
    pub controller: ControllerKind,
    pub arrivals: ArrivalKind,
    /// Half-working-set pool (contention is what the controller manages).
    pub pool_blocks: usize,
    pub eviction: EvictionKind,
    /// TTFT SLO on the virtual clock (goodput + shedding + EDF slack).
    pub slo_s: f64,
    pub max_new: usize,
    /// Output-token budget of the cell.
    pub tokens: usize,
}

/// Requests per cell the budget is sized for (matches the arrivals cells).
const CELL_REQUESTS: usize = 12;

/// The canonical chaos cell: the arrivals experiment's contended shape
/// with a fault plan layered on top.
pub fn chaos_cell(faults: &str, controller: ControllerKind, seed: u64) -> FaultCell {
    let max_new = 120usize;
    let sample = RequestStream::new(cell_workload(), seed, max_new).take(8);
    FaultCell {
        faults: faults.to_string(),
        fault_process: "off".to_string(),
        controller,
        arrivals: ArrivalKind::bursty(2.0),
        pool_blocks: constrained_pool_blocks(&sample, 4),
        eviction: EvictionKind::Lru,
        slo_s: 0.5,
        max_new,
        tokens: CELL_REQUESTS * max_new,
    }
}

fn cell_workload() -> Workload {
    Workload::by_name("code+math").expect("known mix")
}

/// Offered-load axis of the saturation sweep (mean Poisson req/s).
pub const SATURATION_RATES: &[f64] = &[0.5, 1.0, 2.0, 4.0];

/// Stochastic fault process every saturation cell serves under: straggler
/// episodes with a 1.5 s MTBF and 0.4 s MTTR — frequent enough that each
/// cell rides through several fault/repair cycles.
pub const SATURATION_PROCESS: &str = "mtbf=1.5,mttr=0.4,kind=straggler";

/// One saturation cell: open-loop Poisson arrivals at `rate` into the
/// chaos shape (same pool, eviction, SLO, and budget), every cell under
/// the [`SATURATION_PROCESS`] renewal process. Shared by `figure faults`
/// and the bench BENCH_saturation.json emitter so the axes never drift.
pub fn saturation_cell(rate: f64, controller: ControllerKind, seed: u64) -> FaultCell {
    FaultCell {
        fault_process: SATURATION_PROCESS.to_string(),
        arrivals: ArrivalKind::Poisson { rate },
        ..chaos_cell("off", controller, seed)
    }
}

/// Serve one fault cell on the sim backend at batch 4 with 2 expert
/// shards (shard-scoped faults need a topology to act on).
pub fn run_cell(
    ctx: &ExpCtx,
    model: &str,
    policy: &PolicyKind,
    cell: &FaultCell,
) -> Result<BatchRunMetrics> {
    let mut cfg = ctx.batch_cfg(model, 4);
    cfg.max_new_tokens = cell.max_new;
    cfg.kv_pool_blocks = cell.pool_blocks;
    cfg.eviction = cell.eviction;
    cfg.max_preemptions_per_req = 64;
    cfg.admission = AdmissionKind::Edf;
    cfg.slo_s = cell.slo_s;
    cfg.shards = 2;
    cfg.faults = cell.faults.clone();
    cfg.fault_process = cell.fault_process.clone();
    cfg.controller = cell.controller;
    let mut engine = ctx.batch_engine(cfg, policy)?;
    let stream = RequestStream::new(cell_workload(), ctx.seed, cell.max_new);
    let arrivals = ArrivalProcess::new(cell.arrivals.clone(), stream, ctx.seed)?;
    let mut sched = Scheduler::with_arrivals(
        arrivals,
        Budget { max_tokens: cell.tokens, max_requests: 10_000 },
    );
    sched.run_batched(&mut engine)
}

/// `figure faults`: SLO goodput, latency tails, and fault telemetry for
/// every builtin plan (plus fault-free), controller off vs adaptive.
pub fn faults(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let probe = chaos_cell("off", ControllerKind::Off, ctx.seed);
    let mut t = Table::new(
        format!(
            "Fault injection (sim backend, code+math mix, batch 4, 2 shards): \
             {} into a {}-block pool (eviction=lru, admission=edf), SLO {:.0}ms TTFT",
            probe.arrivals.label(),
            probe.pool_blocks,
            1e3 * probe.slo_s
        ),
        &[
            "faults",
            "controller",
            "reqs",
            "tokens",
            "TPOT",
            "TTFT p95",
            "E2E p99",
            "goodput",
            "shed",
            "events",
            "stall ms",
            "degraded",
            "recovery s",
        ],
    );
    let policy = PolicyKind::Static(3);
    let mut plans: Vec<&str> = vec!["off"];
    plans.extend(BUILTIN_PLANS.iter().map(|(name, _)| *name));
    for plan in plans {
        for controller in [ControllerKind::Off, ControllerKind::Adaptive] {
            let cell = chaos_cell(plan, controller, ctx.seed);
            let m = run_cell(ctx, "mixtral", &policy, &cell)?;
            t.row(vec![
                plan.into(),
                controller.label().into(),
                m.run.requests.len().to_string(),
                m.run.total_tokens().to_string(),
                ms(m.tpot_s()),
                ms(m.run.ttft_percentile(0.95)),
                ms(m.run.e2e_percentile(0.99)),
                format!("{:.0}%", 100.0 * m.run.slo_goodput(cell.slo_s)),
                m.sheds.to_string(),
                m.fault_events.to_string(),
                format!("{:.1}", 1e3 * m.stall_s()),
                format!("{:.0}%", 100.0 * m.degraded_fraction()),
                format!("{:.2}", m.recovery_s),
            ]);
        }
    }
    Ok(vec![t, saturation_table(ctx)?, resolved_plans_table(ctx.seed)])
}

/// Goodput vs offered load: sweep the Poisson arrival rate with the
/// degradation controller off vs adaptive, every cell under the same
/// stochastic MTBF straggler process. The saturation knee — where goodput
/// stops tracking offered load — moves right with the controller on.
pub fn saturation_table(ctx: &ExpCtx) -> Result<Table> {
    let policy = PolicyKind::Static(3);
    let mut t = Table::new(
        format!(
            "Goodput vs offered load (sim backend, code+math mix, batch 4, 2 shards): \
             Poisson arrivals under fault process `{SATURATION_PROCESS}`"
        ),
        &[
            "rate /s",
            "controller",
            "reqs",
            "tokens",
            "tok/s (virtual)",
            "TPOT",
            "TTFT p95",
            "goodput",
            "shed",
            "events",
            "degraded",
        ],
    );
    for &rate in SATURATION_RATES {
        for controller in [ControllerKind::Off, ControllerKind::Adaptive] {
            let cell = saturation_cell(rate, controller, ctx.seed);
            let m = run_cell(ctx, "mixtral", &policy, &cell)?;
            t.row(vec![
                format!("{rate:.1}"),
                controller.label().into(),
                m.run.requests.len().to_string(),
                m.run.total_tokens().to_string(),
                format!("{:.1}", m.run.total_tokens() as f64 / m.clock_s),
                ms(m.tpot_s()),
                ms(m.run.ttft_percentile(0.95)),
                format!("{:.0}%", 100.0 * m.run.slo_goodput(cell.slo_s)),
                m.sheds.to_string(),
                m.fault_events.to_string(),
                format!("{:.0}%", 100.0 * m.degraded_fraction()),
            ]);
        }
    }
    Ok(t)
}

/// Every builtin plan's resolved spec (`FaultPlan::parse` → `to_spec`,
/// the round-trip grammar), plus the saturation sweep's stochastic
/// process materialized at this seed — so `figure faults` shows exactly
/// which events each named plan and process expand into.
fn resolved_plans_table(seed: u64) -> Table {
    let mut t = Table::new(
        "Resolved fault plans (parse -> to_spec round-trip)",
        &["plan", "resolved spec"],
    );
    for (name, _) in BUILTIN_PLANS {
        let plan = FaultPlan::parse(name).expect("builtin plan parses").to_spec();
        t.row(vec![(*name).into(), plan]);
    }
    let process = FaultProcess::parse(SATURATION_PROCESS)
        .expect("saturation process parses")
        .expect("saturation process is not off");
    t.row(vec![
        format!("process `{SATURATION_PROCESS}`"),
        process.materialize(seed, 2, PROCESS_HORIZON_S).to_spec(),
    ]);
    t
}
